"""End-to-end ANNS serving driver (the paper's deployment scenario):
variable-size batched requests against a prebuilt index through the
batch-serving engine (repro.serve) — shape-bucketed compile cache, early
termination tuned to a recall target, quantized (SQ) first-pass + exact
re-rank, and per-request latency/recall telemetry.

    PYTHONPATH=src python examples/serve_ann.py
"""
import numpy as np

from repro.core.index import KBest
from repro.core.tune import tune_early_term
from repro.core.types import (BuildConfig, IndexConfig, QuantConfig,
                              SearchConfig)
from repro.data.vectors import make_dataset
from repro.serve import Request, SearchEngine, serve_loop


def main():
    ds = make_dataset("deep_like", n=4000, n_queries=200, k=10)
    config = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=32, knn_k=48, refine_iters=1, reorder="mst"),
        search=SearchConfig(L=64, k=10),
        quant=QuantConfig(kind="sq"),           # int8 store + exact re-rank
    )
    index = KBest(config).add(ds.base)

    # --- offline: tune early termination under a recall constraint -------
    held_q, held_gt = ds.queries[:50], ds.gt_ids[:50]
    tuned = tune_early_term(index, held_q, held_gt,
                            SearchConfig(L=64, k=10), recall_target=0.95)
    print(f"tuned early-term: t_frac={tuned.et_t_frac} "
          f"patience={tuned.et_patience}")

    # --- online: serve the remaining queries in variable-size batches ----
    engine = SearchEngine(index, min_bucket=8, max_bucket=32)
    engine.warmup([32], search_cfg=tuned)       # precompile the hot bucket
    batch_size = 32
    requests = [
        # the final batch is PARTIAL (150 % 32 != 0): recall and latency
        # denominators must use the true per-request counts, not
        # ceil-batches * batch_size — serve_loop accounts per served query
        Request(queries=ds.queries[s:s + batch_size],
                gt_ids=ds.gt_ids[s:s + batch_size], search_cfg=tuned)
        for s in range(50, 200, batch_size)
    ]
    report = serve_loop(engine, requests, coalesce=False)
    st = report.engine_stats[engine.name]
    per_q = st.mean_lat_ms * st.n_requests / max(st.n_queries, 1)
    print(f"served {report.n_served} queries | "
          f"recall@10={report.recall_at_k:.3f} | "
          f"mean latency {per_q:.2f} ms/q (CPU interpret) | "
          f"p95 {report.lat_p95_ms:.2f} ms/batch")
    print("engine:", st.summary())
    assert report.n_served == 150, report.n_served


if __name__ == "__main__":
    main()
