"""End-to-end ANNS serving driver (the paper's deployment scenario):
batched requests against a prebuilt index, with early termination tuned to
a recall target, quantized (SQ) first-pass + exact re-rank, and latency
accounting per batch.

    PYTHONPATH=src python examples/serve_ann.py
"""
import dataclasses
import time

import numpy as np

from repro.core.index import KBest
from repro.core.tune import tune_early_term
from repro.core.types import (BuildConfig, IndexConfig, QuantConfig,
                              SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k


def main():
    ds = make_dataset("deep_like", n=4000, n_queries=200, k=10)
    config = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=32, knn_k=48, refine_iters=1, reorder="mst"),
        search=SearchConfig(L=64, k=10),
        quant=QuantConfig(kind="sq"),           # int8 store + exact re-rank
    )
    index = KBest(config).add(ds.base)

    # --- offline: tune early termination under a recall constraint -------
    held_q, held_gt = ds.queries[:50], ds.gt_ids[:50]
    tuned = tune_early_term(index, held_q, held_gt,
                            SearchConfig(L=64, k=10), recall_target=0.95)
    print(f"tuned early-term: t_frac={tuned.et_t_frac} "
          f"patience={tuned.et_patience}")

    # --- online: batched request loop ------------------------------------
    batch_size = 32
    lat = []
    hits = 0
    index.search(ds.queries[:batch_size], search_cfg=tuned)   # warmup/jit
    for s in range(50, 200, batch_size):
        q = ds.queries[s:s + batch_size]
        t0 = time.perf_counter()
        d, i = index.search(q, search_cfg=tuned)
        np.asarray(d)
        lat.append((time.perf_counter() - t0) / len(q) * 1e3)
        hits += recall_at_k(np.asarray(i), ds.gt_ids[s:s + batch_size], 10) \
            * len(q)
    total = len(range(50, 200, batch_size)) * batch_size
    print(f"served {total} queries | recall@10={hits/total:.3f} | "
          f"mean latency {np.mean(lat):.2f} ms/q (CPU interpret) | "
          f"p95 {np.percentile(lat, 95):.2f} ms/q")


if __name__ == "__main__":
    main()
