"""Quickstart: build a KBest index (both families), search it, save/load.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k


def main():
    # 1. data: a synthetic SIFT-like corpus (see repro/data/vectors.py)
    ds = make_dataset("bigann_like", n=3000, n_queries=50, k=10)

    # 2. parameter preparation (paper Table 2: KBest(config))
    config = IndexConfig(
        dim=ds.base.shape[1],
        metric="l2",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          refine_iters=1, reorder="mst"),
        search=SearchConfig(L=192, k=10, early_term=True, et_patience=48),
    )

    # 3. index construction (paper: Add(n, x))
    index = KBest(config).add(ds.base)

    # 4. query processing (paper: Search(nq, q, k, nt))
    dists, ids, stats = index.search(ds.queries, k=10, with_stats=True)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    print(f"recall@10          = {rec:.3f}")
    print(f"hops/query         = {float(np.asarray(stats.n_hops).mean()):.1f}")
    print(f"dists/query        = {float(np.asarray(stats.n_dist).mean()):.0f}")
    print(f"early-term rate    = {float(np.asarray(stats.early_terminated).mean()):.2f}")

    # 5. persistence
    index.save("/tmp/kbest_quickstart.npz")
    index2 = KBest.load("/tmp/kbest_quickstart.npz")
    d2, i2 = index2.search(ds.queries[:5], k=10)
    print("reloaded index answers:", np.asarray(i2)[0][:5], "...")

    # 6. the partition-based sibling: IVF-PQ behind the same facade
    #    (k-means coarse quantizer + residual PQ + exact re-rank)
    ivf_config = IndexConfig(
        dim=ds.base.shape[1], metric="l2", index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=8),       # nlist=0 => sqrt(n)
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=6),
        search=SearchConfig(L=128, k=10, nprobe=16),
    )
    ivf_index = KBest(ivf_config).add(ds.base)
    dists, ids, stats = ivf_index.search(ds.queries, k=10, with_stats=True)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    print(f"ivf recall@10      = {rec:.3f}")
    print(f"ivf codes scanned  = {float(np.asarray(stats.n_dist).mean()):.0f}/query")

    ivf_index.save("/tmp/kbest_quickstart_ivf.npz")
    ivf2 = KBest.load("/tmp/kbest_quickstart_ivf.npz")
    d3, i3 = ivf2.search(ds.queries[:5], k=10)
    print("reloaded ivf answers:", np.asarray(i3)[0][:5], "...")


if __name__ == "__main__":
    main()
