"""RecSys retrieval serving — the paper's technique as a first-class
feature (DESIGN.md §5): score 1 query against a large candidate corpus,
two ways, and compare:

  exact : the H1 batched 1-to-B inner-product (MXU batch_dist kernel)
  ann   : KBest graph index over the item-embedding table (sub-linear)

    PYTHONPATH=src python examples/retrieval_recsys.py
"""
import time

import jax
import numpy as np

from repro import configs as reg
from repro.core.index import KBest
from repro.core.types import BuildConfig, IndexConfig, SearchConfig
from repro.models import recsys as R


def main():
    import dataclasses
    cfg = dataclasses.replace(reg.get("bst").smoke_config(), n_items=4000)
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"hist": rng.integers(0, cfg.n_items, (8, cfg.seq_len))}

    # --- exact path: 1-to-B batched dot over ALL candidates --------------
    t0 = time.perf_counter()
    d_exact, i_exact = R.serve_retrieval(params, batch, cfg, k=10)
    np.asarray(d_exact)
    t_exact = time.perf_counter() - t0

    # --- ANN path: KBest index over the item table ------------------------
    corpus = np.asarray(R.candidate_table(params, cfg))
    idx_cfg = IndexConfig(
        dim=corpus.shape[1], metric="ip",
        build=BuildConfig(M=24, knn_k=32, refine_iters=1),
        search=SearchConfig(L=64, k=10, early_term=True))
    index = KBest(idx_cfg).add(corpus)
    q = np.asarray(R.query_vector(params, batch, cfg))
    index.search(q[:1], k=10)                      # warmup/jit
    t0 = time.perf_counter()
    d_ann, i_ann = index.search(q, k=10)
    np.asarray(d_ann)
    t_ann = time.perf_counter() - t0

    # --- compare -----------------------------------------------------------
    overlap = np.mean([
        len(set(np.asarray(i_exact)[b].tolist())
            & set(np.asarray(i_ann)[b].tolist())) / 10
        for b in range(q.shape[0])])
    print(f"exact 1-to-B : {t_exact*1e3:7.1f} ms  (scored {corpus.shape[0]} items/query)")
    print(f"kbest ANN    : {t_ann*1e3:7.1f} ms")
    print(f"ANN recall vs exact top-10: {overlap:.2f}")


if __name__ == "__main__":
    main()
