"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps with the full production substrate — AdamW, checkpoints, auto-resume,
straggler tracking, background-prefetched data.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""
import argparse

import jax

from repro.data.pipeline import Prefetcher, lm_batches
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig


def model_100m():
    # ~103M params: 12L x d512 x ffn2048, vocab 32k
    return LMConfig(name="lm-100m", n_layers=12, d_model=512, n_heads=8,
                    n_kv_heads=8, d_ff=2048, vocab=32_000,
                    dtype="float32", remat=False)


def model_tiny():
    return LMConfig(name="lm-tiny", n_layers=2, d_model=128, n_heads=4,
                    n_kv_heads=4, d_ff=256, vocab=1024, dtype="float32",
                    remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer model (CI-sized)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", type=str, default="/tmp/kbest_lm_ckpt")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    n_params = sum(p.size for p in jax.tree.leaves(
        jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    params = init_params(cfg, jax.random.PRNGKey(0))
    data = Prefetcher(lm_batches(cfg.vocab, args.batch, args.seq,
                                 structured=True))
    trainer = Trainer(
        lambda p, b: loss_fn(p, b, cfg),
        OptConfig(lr=3e-4, grad_clip=1.0),
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=50, log_every=10))
    trainer.install_signal_handler()   # SIGTERM -> checkpoint + exit
    out = trainer.fit(params, data, n_steps=args.steps, resume=True)
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  {h['sec']*1e3:.0f} ms")
    print(f"stragglers observed: {out['stragglers']}")


if __name__ == "__main__":
    main()
