"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+1 shared) — the assignment specifies
the text backbone; early-fusion multimodal frontend is out of scope
(modality stub). [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs import LM_SHAPES
from repro.layers.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "llama4-scout-17b-a16e"
FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202_048, head_dim=128,
        act="silu", gated_mlp=True, dtype="bfloat16", remat=True,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                      n_shared_experts=1, capacity_factor=1.25))


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
        act="silu", gated_mlp=True, dtype="float32", remat=False,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128,
                      n_shared_experts=1))
