"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
— GeGLU, head_dim=256, tied embeddings, embedding scaling. [arXiv:2403.08295]"""
from repro.configs import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "gemma-2b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab=256_000, head_dim=256,
        act="geglu", gated_mlp=True, tie_embeddings=True,
        dtype="bfloat16", remat=True)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=256, vocab=512, head_dim=64,
        act="geglu", gated_mlp=True, tie_embeddings=True,
        dtype="float32", remat=False)
