"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared, DeepSeek-style) — trillion-
param MoE. [arXiv:2501.kimi2; paper-table]

Total params ~= 61 * 384 * 3*7168*2048 ~= 1.03e12; active ~32B/token.
"""
from repro.configs import LM_SHAPES
from repro.layers.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "kimi-k2-1t-a32b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab=163_840, head_dim=112,
        act="silu", gated_mlp=True, dtype="bfloat16", remat=True,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                      n_shared_experts=1, capacity_factor=1.25))


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, head_dim=32,
        act="silu", gated_mlp=True, dtype="float32", remat=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                      n_shared_experts=1))
