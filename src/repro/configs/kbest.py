"""The paper's own system configs: recommended KBest index parameters per
evaluation dataset (paper Table 3/4), exposed like the arch configs.

    from repro.configs import kbest
    cfg = kbest.index_config("bigann_like")            # graph index
    cfg = kbest.ivf_index_config("bigann_like")        # IVF-PQ index
    cfg = kbest.sharded_index_config("bigann_like", 4) # 4-shard graph mesh

Graph presets tune the build/search pipeline of DESIGN.md §3; the IVF
presets (DESIGN.md §4) tune (nlist auto, nprobe, pq_m) to reach
recall@10 >= 0.90 on the 50k synthetic analogues with full-queue re-rank.
The sharded presets (DESIGN.md §12) stamp n_shards onto the same tuned
configs — build them with repro.core.sharded.ShardedKBest.
"""
import dataclasses

from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)

ARCH_ID = "kbest"
FAMILY = "anns"
SHAPES = ("glove_like", "deep_like", "t2i_like", "bigann_like")

# (dim, metric, build, search) tuned on the synthetic analogues to reach
# recall@10 >= 0.95 (benchmarks/qps_recall.py)
_CONFIGS = {
    "glove_like": dict(
        dim=100, metric="ip",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=2, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=128, k=10, early_term=True, et_patience=32)),
    "deep_like": dict(
        dim=96, metric="ip",
        build=BuildConfig(M=24, knn_k=32, select_rule="alpha", alpha=1.2,
                          search_passes=1, refine_iters=1, refine_cands=64,
                          reorder="mst"),
        search=SearchConfig(L=64, k=10, early_term=True, et_patience=16)),
    "t2i_like": dict(
        dim=200, metric="ip",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=1, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=128, k=10, early_term=True, et_patience=32)),
    "bigann_like": dict(
        dim=128, metric="l2",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=1, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=192, k=10, early_term=True, et_patience=48)),
}


# IVF-PQ presets: pq_m must divide dim; nprobe/L tuned for the re-ranked
# pipeline (candidate recall == final recall with rerank=0 => full queue).
_IVF_CONFIGS = {
    "glove_like": dict(dim=100, metric="ip", pq_m=20, nprobe=32, L=192),
    "deep_like": dict(dim=96, metric="ip", pq_m=16, nprobe=24, L=128),
    "t2i_like": dict(dim=200, metric="ip", pq_m=20, nprobe=32, L=192),
    "bigann_like": dict(dim=128, metric="l2", pq_m=16, nprobe=32, L=192),
}


# pq4 presets (DESIGN.md §13): 4-bit codes are coarser per subspace, so the
# presets spend (some of) the halved bytes on more subspaces and widen the
# re-ranked candidate queue / probe count to hold the recall floor.
_IVF_PQ4_CONFIGS = {
    "glove_like": dict(dim=100, metric="ip", pq_m=20, nprobe=48, L=256),
    "deep_like": dict(dim=96, metric="ip", pq_m=32, nprobe=32, L=192),
    "t2i_like": dict(dim=200, metric="ip", pq_m=40, nprobe=48, L=256),
    "bigann_like": dict(dim=128, metric="l2", pq_m=32, nprobe=48, L=384),
}


# bin presets (DESIGN.md §14): 1-bit Hamming first pass needs a wider
# queue and a deep exact rescore — (L, rescore_factor) on the graph side,
# (nprobe, ivf_L, ivf_rescore_factor) on the IVF side — to hold the 0.90
# recall floor at 32x-smaller-than-f32 codes. The IVF flat scan keeps no
# traversal queue, so its overfetch must be much deeper than the graph's
# (the graph's Hamming-ordered frontier already concentrates true
# neighbours near the top): deep_like at 50k measures 0.92 at
# nprobe=96/rf=64 but only 0.85 at nprobe=64/rf=32.
_BIN_CONFIGS = {
    "glove_like": dict(L=320, rescore_factor=32,
                       nprobe=96, ivf_L=768, ivf_rescore_factor=64),
    "deep_like": dict(L=320, rescore_factor=32,
                      nprobe=96, ivf_L=768, ivf_rescore_factor=64),
    "t2i_like": dict(L=320, rescore_factor=32,
                     nprobe=96, ivf_L=768, ivf_rescore_factor=64),
    "bigann_like": dict(L=384, rescore_factor=32,
                        nprobe=96, ivf_L=768, ivf_rescore_factor=64),
}


def index_config(dataset: str) -> IndexConfig:
    return IndexConfig(**_CONFIGS[dataset])


def bin_index_config(dataset: str) -> IndexConfig:
    """Graph preset with the 1-bit sign codec (DESIGN.md §14): Hamming
    traversal over u32-packed codes + exact rescore of the
    rescore_factor*k overfetch."""
    cfg = index_config(dataset)
    b = _BIN_CONFIGS[dataset]
    return dataclasses.replace(
        cfg,
        quant=QuantConfig(kind="bin"),
        search=dataclasses.replace(cfg.search, L=b["L"],
                                   rescore_factor=b["rescore_factor"]))


def sq_index_config(dataset: str) -> IndexConfig:
    """Graph preset with the int8 scalar quantizer (DESIGN.md §13): per-dim
    affine u8 codes traversed directly (gather+dequant fused in the kernel
    path), exact re-rank of the default 4*k overfetch. SQ is nearly
    recall-transparent at 4x-smaller codes, so the tuned graph knobs carry
    over unchanged."""
    return dataclasses.replace(index_config(dataset),
                               quant=QuantConfig(kind="sq"))


def ivf_bin_index_config(dataset: str) -> IndexConfig:
    """IVF preset with the 1-bit sign codec (DESIGN.md §14): XOR+popcount
    list scans (no LUT stage) + exact rescore. The deep_like preset is the
    50k acceptance config of tests/test_bin."""
    c = _IVF_CONFIGS[dataset]
    b = _BIN_CONFIGS[dataset]
    return IndexConfig(
        dim=c["dim"], metric=c["metric"], index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=10),
        quant=QuantConfig(kind="bin"),
        search=SearchConfig(L=b["ivf_L"], k=10, nprobe=b["nprobe"],
                            rescore_factor=b["ivf_rescore_factor"]))


def ivf_index_config(dataset: str) -> IndexConfig:
    c = _IVF_CONFIGS[dataset]
    return IndexConfig(
        dim=c["dim"], metric=c["metric"], index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=10),
        quant=QuantConfig(kind="pq", pq_m=c["pq_m"], kmeans_iters=8),
        search=SearchConfig(L=c["L"], k=10, nprobe=c["nprobe"]))


def ivf_pq4_index_config(dataset: str) -> IndexConfig:
    """4-bit fast-scan IVF presets (half the code bytes of ivf_index_config
    at equal m; these double m where dim allows, trading bytes for recall).
    The bigann_like preset is the 50k acceptance config of tests/test_pq4."""
    c = _IVF_PQ4_CONFIGS[dataset]
    return IndexConfig(
        dim=c["dim"], metric=c["metric"], index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=10),
        quant=QuantConfig(kind="pq4", pq_m=c["pq_m"], kmeans_iters=10),
        search=SearchConfig(L=c["L"], k=10, nprobe=c["nprobe"]))


# Beam presets (DESIGN.md §2): W per dataset, tuned so the beam cuts
# lockstep iterations ~W x at equal recall on the 50k analogues
# (benchmarks/traverse.py measures the trade; W=1 == classic best-first).
_BEAM_W = {"glove_like": 4, "deep_like": 4, "t2i_like": 4, "bigann_like": 4}


def beam_index_config(dataset: str, beam_width: int = 0) -> IndexConfig:
    """Graph preset searched with beam-parallel traversal (DESIGN.md §2):
    top-W unvisited candidates expand per lockstep iteration, feeding the
    fused gather+distance+merge step W*M candidates at once. beam_width=0
    takes the per-dataset tuned width; ET patience is per-expansion (Eq. 3
    in beam order), so the preset patience needs no rescaling."""
    cfg = index_config(dataset)
    w = beam_width if beam_width > 0 else _BEAM_W[dataset]
    return dataclasses.replace(
        cfg, search=dataclasses.replace(cfg.search, beam_width=w))


def sharded_index_config(dataset: str, n_shards: int = 2) -> IndexConfig:
    """Graph preset on an n_shards mesh (DESIGN.md §12). Per-shard knobs
    are the single-shard tuning: each shard runs the full traversal at the
    preset L, so the merged recall only goes up (scaling.py measures the
    cost side)."""
    return dataclasses.replace(index_config(dataset), n_shards=n_shards)


def sharded_ivf_index_config(dataset: str, n_shards: int = 2) -> IndexConfig:
    """IVF-PQ preset on an n_shards mesh: every shard trains its own coarse
    centroids (nlist=0 => sqrt(n_shard)) and probes nprobe of them, so the
    total scanned lists grow with the mesh — recall floor holds per shard."""
    return dataclasses.replace(ivf_index_config(dataset), n_shards=n_shards)


def sharded_ivf_pq4_index_config(dataset: str,
                                 n_shards: int = 2) -> IndexConfig:
    """4-bit fast-scan IVF preset on an n_shards mesh (DESIGN.md §12+§13:
    quantized shard-local scan, shard-local exact re-rank, global merge)."""
    return dataclasses.replace(ivf_pq4_index_config(dataset),
                               n_shards=n_shards)


def sharded_bin_index_config(dataset: str, n_shards: int = 2) -> IndexConfig:
    """1-bit sign-codec graph preset on an n_shards mesh (DESIGN.md
    §12+§14: shard-local Hamming traversal + exact rescore, global merge)."""
    return dataclasses.replace(bin_index_config(dataset), n_shards=n_shards)


def sharded_smoke_config(n_shards: int = 2) -> IndexConfig:
    """Tiny sharded-graph config for CI-speed mesh tests."""
    return dataclasses.replace(smoke_config(), n_shards=n_shards)


def full_config(dataset: str = "bigann_like") -> IndexConfig:
    return index_config(dataset)


def smoke_config() -> IndexConfig:
    return IndexConfig(
        dim=32, metric="l2",
        build=BuildConfig(M=8, knn_k=12, refine_iters=1, refine_cands=24,
                          reorder="mst"),
        search=SearchConfig(L=16, k=5))


def tune_grid(index_type: str) -> dict:
    """Search-knob grid core/tune.py::tune_config sweeps (DESIGN.md §16).
    Quant kinds are NOT enumerated here — the tuner takes them from the
    registry (types.QUANT_KINDS / quantize.IVF_QUANT_KINDS), so a new
    kind lands in the tuner automatically. rescore_factor only fans out
    for kind="bin" (the only kind that reads it)."""
    if index_type == "ivf":
        return {"L": (32, 64, 128, 256), "nprobe": (4, 8, 16, 32, 64),
                "rescore_factor": (8, 32)}
    return {"L": (32, 64, 128, 256), "beam_width": (1, 4),
            "rescore_factor": (8, 32)}


def degrade_ladder(cfg: IndexConfig, n_rungs: int = 4) -> tuple:
    """Pre-tuned shed valve for the serving tier (DESIGN.md §17): rung 0 is
    the preset's own SearchConfig; each further rung halves the dominant
    accuracy/cost knobs — L and rescore_factor always, nprobe on the IVF
    path — subject to the k <= L / beam_width <= L invariants. Candidates
    that don't STRICTLY lower the predicted per-query cost are dropped
    (e.g. halving L below the quantized wide-queue floor), so the ladder is
    monotone cost-decreasing by construction; every rung is a valid
    standalone SearchConfig (both pinned by tests/test_degrade.py)."""
    from repro.analysis.cost import predict_service_s
    s = cfg.search
    ladder = [s]
    last_cost = predict_service_s(cfg, s)
    while len(ladder) < n_rungs:
        cand = dataclasses.replace(
            s,
            L=max(s.k, s.beam_width, s.L // 2),
            nprobe=max(1, s.nprobe // 2),
            rescore_factor=max(1, s.rescore_factor // 2))
        if cand == s:
            break                        # every knob is at its floor
        s = cand
        c = predict_service_s(cfg, s)
        if c < last_cost * 0.999:
            ladder.append(s)
            last_cost = c
    return tuple(ladder)


def ivf_smoke_config() -> IndexConfig:
    return IndexConfig(
        dim=32, metric="l2", index_type="ivf",
        ivf=IVFConfig(nlist=8, kmeans_iters=4, list_pad=8),
        quant=QuantConfig(kind="pq", pq_m=8, kmeans_iters=3),
        search=SearchConfig(L=16, k=5, nprobe=4))
