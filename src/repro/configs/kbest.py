"""The paper's own system configs: recommended KBest index parameters per
evaluation dataset (paper Table 3/4), exposed like the arch configs.

    from repro.configs import kbest
    cfg = kbest.index_config("bigann_like")
"""
from repro.core.types import BuildConfig, IndexConfig, QuantConfig, SearchConfig

ARCH_ID = "kbest"
FAMILY = "anns"
SHAPES = ("glove_like", "deep_like", "t2i_like", "bigann_like")

# (dim, metric, build, search) tuned on the synthetic analogues to reach
# recall@10 >= 0.95 (benchmarks/qps_recall.py)
_CONFIGS = {
    "glove_like": dict(
        dim=100, metric="ip",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=2, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=128, k=10, early_term=True, et_patience=32)),
    "deep_like": dict(
        dim=96, metric="ip",
        build=BuildConfig(M=24, knn_k=32, select_rule="alpha", alpha=1.2,
                          search_passes=1, refine_iters=1, refine_cands=64,
                          reorder="mst"),
        search=SearchConfig(L=64, k=10, early_term=True, et_patience=16)),
    "t2i_like": dict(
        dim=200, metric="ip",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=1, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=128, k=10, early_term=True, et_patience=32)),
    "bigann_like": dict(
        dim=128, metric="l2",
        build=BuildConfig(M=32, knn_k=48, select_rule="alpha", alpha=1.2,
                          search_passes=2, refine_iters=1, refine_cands=96,
                          reorder="mst"),
        search=SearchConfig(L=192, k=10, early_term=True, et_patience=48)),
}


def index_config(dataset: str) -> IndexConfig:
    return IndexConfig(**_CONFIGS[dataset])


def full_config(dataset: str = "bigann_like") -> IndexConfig:
    return index_config(dataset)


def smoke_config() -> IndexConfig:
    return IndexConfig(
        dim=32, metric="l2",
        build=BuildConfig(M=8, knn_k=12, refine_iters=1, refine_cands=24,
                          reorder="mst"),
        search=SearchConfig(L=16, k=5))
