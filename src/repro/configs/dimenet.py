"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6. [arXiv:2003.03123]

Per-shape input parameters (assigned):
  full_graph_sm : n_nodes=2708   n_edges=10556      d_feat=1433 (full-batch)
  minibatch_lg  : n_nodes=232965 n_edges=114615892  batch_nodes=1024
                  fanout 15-10 (sampled; d_feat=602, Reddit's)
  ogb_products  : n_nodes=2449029 n_edges=61859140  d_feat=100 (full-batch)
  molecule      : n_nodes=30 n_edges=64 batch=128 (batched small graphs)

Triplet expansion is capped at TRIPLET_CAP per edge on the big graphs
(DESIGN.md §Arch-applicability: full expansion of 61.9M edges would be
~1.5G triplets).
"""
from repro.configs import GNN_SHAPES
from repro.models.dimenet import DimeNetConfig

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = GNN_SHAPES
TRIPLET_CAP = 8

SHAPE_PARAMS = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          task="node_clf", n_out=7),
    "minibatch_lg": dict(n_nodes=232_965, n_edges=114_615_892,
                         batch_nodes=1024, fanouts=(15, 10), d_feat=602,
                         task="node_clf", n_out=41),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         task="node_clf", n_out=47),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=32,
                     task="graph_reg", n_out=1),
}


def full_config(shape: str = "full_graph_sm") -> DimeNetConfig:
    sp = SHAPE_PARAMS[shape]
    return DimeNetConfig(
        name=ARCH_ID, n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
        n_radial=6, d_feat=sp["d_feat"], n_out=sp["n_out"], task=sp["task"],
        dtype="float32")


def smoke_config() -> DimeNetConfig:
    return DimeNetConfig(
        name=ARCH_ID + "-smoke", n_blocks=2, d_hidden=32, n_bilinear=4,
        n_spherical=3, n_radial=4, d_feat=16, n_out=4, task="node_clf",
        dtype="float32")
