"""deepfm [recsys]: n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm.
[arXiv:1703.04247]"""
from repro.configs import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH_ID = "deepfm"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID, kind="deepfm", n_sparse=39, vocab_per_field=1_000_000,
        embed_dim=10, mlp_dims=(400, 400, 400), dtype="float32")


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke", kind="deepfm", n_sparse=6,
        vocab_per_field=1000, embed_dim=8, mlp_dims=(32, 16),
        dtype="float32")
