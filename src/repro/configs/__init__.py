"""Architecture registry: one module per assigned arch (+ the paper's own
ANNS configs in kbest.py). Each module exposes

    ARCH_ID:  str
    FAMILY:   "lm" | "gnn" | "recsys"
    SHAPES:   tuple of shape names valid for this arch
    full_config()   -> model config (exact assigned hyperparameters)
    smoke_config()  -> reduced same-family config for CPU smoke tests

Select with --arch <id> in the launchers.
"""
from __future__ import annotations

import importlib

ARCHS = (
    # LM family
    "qwen2_5_14b",
    "chatglm3_6b",
    "gemma_2b",
    "kimi_k2_1t_a32b",
    "llama4_scout_17b_a16e",
    # GNN
    "dimenet",
    # RecSys
    "deepfm",
    "bert4rec",
    "bst",
    "fm",
)

_ALIAS = {
    "qwen2.5-14b": "qwen2_5_14b",
    "chatglm3-6b": "chatglm3_6b",
    "gemma-2b": "gemma_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
}

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")


def get(arch: str):
    name = _ALIAS.get(arch, arch.replace("-", "_").replace(".", "_"))
    assert name in ARCHS, f"unknown arch {arch}; options: {ARCHS}"
    return importlib.import_module(f"repro.configs.{name}")


def all_cells():
    """All 40 (arch, shape) dry-run cells."""
    for a in ARCHS:
        mod = get(a)
        for s in mod.SHAPES:
            yield a, s
