"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias. [hf:Qwen/Qwen2.5-14B]"""
from repro.configs import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2.5-14b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, head_dim=128, qkv_bias=True,
        act="silu", gated_mlp=True, rope_base=1_000_000.0,
        dtype="bfloat16", remat=True)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, qkv_bias=True,
        act="silu", gated_mlp=True, dtype="float32", remat=False)
