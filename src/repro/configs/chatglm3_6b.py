"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (rotary on half the head dim), GQA. [arXiv:2406.12793]"""
from repro.configs import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "chatglm3-6b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=65024, head_dim=128, qkv_bias=True,
        rotary_frac=0.5,                       # ChatGLM 2-d RoPE
        act="silu", gated_mlp=True, dtype="bfloat16", remat=True)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, qkv_bias=True,
        rotary_frac=0.5, act="silu", gated_mlp=True, dtype="float32",
        remat=False)
