"""fm [recsys]: n_sparse=39 embed_dim=10, pairwise <vi,vj>xixj via the
O(nk) sum-square trick. [Rendle ICDM'10]"""
from repro.configs import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH_ID = "fm"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID, kind="fm", n_sparse=39, vocab_per_field=1_000_000,
        embed_dim=10, dtype="float32")


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke", kind="fm", n_sparse=6, vocab_per_field=1000,
        embed_dim=8, dtype="float32")
