"""bst [recsys]: Behavior Sequence Transformer (Alibaba): embed_dim=32
seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256. [arXiv:1905.06874]"""
from repro.configs import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH_ID = "bst"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID, kind="bst", n_items=1_000_000, seq_len=20,
        n_blocks=1, n_heads=8, d_model=32, mlp_dims=(1024, 512, 256),
        dtype="float32")


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke", kind="bst", n_items=500, seq_len=8,
        n_blocks=1, n_heads=4, d_model=16, mlp_dims=(64, 32),
        dtype="float32")
