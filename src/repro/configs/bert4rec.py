"""bert4rec [recsys]: embed_dim=64 n_blocks=2 n_heads=2 seq_len=200,
bidirectional encoder with masked-item prediction. [arXiv:1904.06690]"""
from repro.configs import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH_ID = "bert4rec"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID, kind="bert4rec", n_items=1_000_000, seq_len=200,
        n_blocks=2, n_heads=2, d_model=64, dtype="float32")


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke", kind="bert4rec", n_items=500, seq_len=12,
        n_blocks=1, n_heads=2, d_model=16, dtype="float32")
