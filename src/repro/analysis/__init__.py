"""kbest-lint: AST-based invariant checks over the KBest tree
(DESIGN.md §15).

Seven checks, each a module with `run(tree) -> List[Violation]`:

  kernel_parity   every Pallas kernel has a jnp oracle, an ops.py
                  dispatch entry, and a kernel-vs-ref parity test
  registry        QUANT_KINDS/quant_variants wired through dispatch,
                  save/load, presets, ablation; no hand quant lists
  dead_knobs      every config dataclass field is read somewhere
  tracing_safety  no Python control flow on traced values in kernel
                  bodies / jit entry points
  vmem_budget     per-kernel BlockSpec+scratch residency under budget
  docs_xref       DESIGN.md §-citations resolve, sections contiguous
  cost            every kernel has a resolvable closed-form cost model
                  (FLOPs / HBM bytes / dists — DESIGN.md §16)

Pure stdlib (`ast` only) — runs without jax installed, and runs on
deliberately-broken fixture trees. CLI: `python -m repro.analysis`.
"""
from pathlib import Path
from typing import Dict, List

from repro.analysis import cost, docs, knobs, parity, registry, tracing, \
    vmem
from repro.analysis.common import Tree, Violation

CHECKS = {
    parity.CHECK: parity.run,
    registry.CHECK: registry.run,
    knobs.CHECK: knobs.run,
    tracing.CHECK: tracing.run,
    vmem.CHECK: vmem.run,
    docs.CHECK: docs.run,
    cost.CHECK: cost.run,
}


def default_root() -> Path:
    """The checkout containing this package: .../src/repro/analysis ->
    three parents up."""
    return Path(__file__).resolve().parents[3]


def run_check(name: str, root) -> List[Violation]:
    return CHECKS[name](Tree(root))


def run_all(root) -> List[Violation]:
    tree = Tree(root)
    out: List[Violation] = []
    for fn in CHECKS.values():
        out.extend(fn(tree))
    return out
