"""Shared AST plumbing for kbest-lint (DESIGN.md §15).

Everything here is pure `ast` over source text — the checks never import
the modules they inspect. That keeps the lint runnable without jax (the
CI lint lane needs only the stdlib), makes it safe on seeded-violation
fixture trees that are deliberately broken, and guarantees the checker
sees the code as written, not as decorated/jitted at import time.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

# Directories never scanned: fixture trees hold deliberate violations,
# __pycache__ holds no source.
EXCLUDED_DIRS = {"analysis_fixtures", "__pycache__"}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One lint finding, pointing at a repo-relative file:line."""
    check: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"


class Tree:
    """Lazy AST view of a checkout rooted at a directory containing
    src/ (and usually tests/ + benchmarks/). Parsed modules are cached;
    files that are missing or unparsable parse to None — checks that
    require them report that as a violation rather than crashing, which
    is what lets minimal fixture trees fire each check."""

    def __init__(self, root) -> None:
        self.root = Path(root)
        self._cache: Dict[str, Optional[ast.Module]] = {}

    def exists(self, rel: str) -> bool:
        return (self.root / rel).is_file()

    def parse(self, rel: str) -> Optional[ast.Module]:
        if rel not in self._cache:
            try:
                src = (self.root / rel).read_text()
                self._cache[rel] = ast.parse(src, filename=rel)
            except (OSError, SyntaxError, ValueError):
                self._cache[rel] = None
        return self._cache[rel]

    def iter_py(self, *subdirs: str) -> Iterator[str]:
        """Repo-relative paths of every .py under the given subtrees,
        sorted, with EXCLUDED_DIRS pruned."""
        for sub in subdirs:
            base = self.root / sub
            if not base.is_dir():
                continue
            for p in sorted(base.rglob("*.py")):
                rel = p.relative_to(self.root)
                # exclusion is root-relative: a fixture tree scanned AS
                # the root is fully visible, but fixture trees inside a
                # scanned checkout stay invisible
                if EXCLUDED_DIRS.intersection(rel.parts):
                    continue
                yield str(rel)


def missing_file(check: str, rel: str, why: str) -> Violation:
    return Violation(check, rel, 1, f"expected file is missing or "
                     f"unparsable ({why})")


# ---------------------------------------------------------------- AST helpers

def top_level_functions(mod: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in mod.body if isinstance(n, ast.FunctionDef)}


def class_def(mod: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for n in mod.body:
        if isinstance(n, ast.ClassDef) and n.name == name:
            return n
    return None


def methods_of(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}


def dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    """(name, lineno) of annotated fields — how frozen-dataclass configs
    declare their knobs (AnnAssign with a plain Name target)."""
    out = []
    for n in cls.body:
        if isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name):
            out.append((n.target.id, n.lineno))
    return out


def referenced_names(node: ast.AST) -> Set[str]:
    """Every Name id and Attribute attr under `node` — the loose
    'does this file mention token X' relation used for parity-test and
    registry-usage checks."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def string_constants(node: ast.AST) -> Set[str]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def calls_to(node: ast.AST, fn_name: str) -> Iterator[ast.Call]:
    """Call sites of `fn_name`, whether spelled bare or as an attribute
    (pl.BlockSpec and BlockSpec both match 'BlockSpec')."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if (isinstance(f, ast.Name) and f.id == fn_name) or \
                    (isinstance(f, ast.Attribute) and f.attr == fn_name):
                yield n


def assigned_tuple_of_strings(mod: ast.Module, var: str
                              ) -> Optional[Tuple[str, ...]]:
    """Value of a module-level `VAR = ("a", "b", ...)` assignment."""
    for n in mod.body:
        if isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == var for t in n.targets):
            if isinstance(n.value, (ast.Tuple, ast.List)):
                elts = n.value.elts
                if all(isinstance(e, ast.Constant) and
                       isinstance(e.value, str) for e in elts):
                    return tuple(e.value for e in elts)
    return None


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None
