"""Check 3 — dead config knobs (DESIGN.md §15).

Every field of SearchConfig / IndexConfig / QuantConfig must be read
somewhere in src/ outside core/types.py. A knob nobody reads is worse
than missing: callers set it, tests sweep it, benchmarks report it — and
nothing changes (the `batch_B` bug, dead for two PRs before anyone
noticed the beam path ignored it).

Liveness is attribute-read based with property bridging: a field only
read by a property on its own class stays live iff that property (or a
property chain from it) is itself read externally — `max_hops` is live
through `hops_bound`, `pq_bits` through `nbits` -> `ksub`.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.common import (Tree, Violation, class_def,
                                   dataclass_fields, missing_file)

CHECK = "dead_knobs"
TYPES = "src/repro/core/types.py"
CLASSES = ("SearchConfig", "IndexConfig", "QuantConfig")
ANALYSIS_PKG = "src/repro/analysis"


def _is_property(fn: ast.FunctionDef) -> bool:
    return any(isinstance(d, ast.Name) and d.id == "property"
               for d in fn.decorator_list)


def _self_reads(fn: ast.FunctionDef) -> Set[str]:
    """Attribute names read off `self` inside a method body."""
    out: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load) \
                and isinstance(n.value, ast.Name) and n.value.id == "self":
            out.add(n.attr)
    return out


def _external_attr_reads(tree: Tree) -> Set[str]:
    """Every attribute name read (Load context) anywhere in src/ outside
    the defining module and the lint package itself."""
    out: Set[str] = set()
    for rel in tree.iter_py("src"):
        if rel == TYPES or rel.startswith(ANALYSIS_PKG):
            continue
        mod = tree.parse(rel)
        if mod is None:
            continue
        for n in ast.walk(mod):
            if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load):
                out.add(n.attr)
    return out


def run(tree: Tree) -> List[Violation]:
    types_mod = tree.parse(TYPES)
    if types_mod is None:
        return [missing_file(CHECK, TYPES, "config dataclasses live here")]

    ext = _external_attr_reads(tree)
    violations: List[Violation] = []
    for cls_name in CLASSES:
        cls = class_def(types_mod, cls_name)
        if cls is None:
            continue
        fields = dataclass_fields(cls)
        props: Dict[str, Set[str]] = {
            m.name: _self_reads(m) for m in cls.body
            if isinstance(m, ast.FunctionDef) and _is_property(m)}

        # Propagate liveness through property chains to a fixpoint:
        # externally-read names are live; anything a live property reads
        # becomes live too.
        live = {n for n, _ in fields if n in ext} | \
               {p for p in props if p in ext}
        changed = True
        while changed:
            changed = False
            for p, reads in props.items():
                if p in live and not reads.issubset(live):
                    live |= reads
                    changed = True

        for name, lineno in fields:
            if name not in live:
                violations.append(Violation(
                    CHECK, TYPES, lineno,
                    f"config knob {cls_name}.{name} is never read outside "
                    f"its defining module (dead knob — the batch_B bug "
                    f"class)"))
    return violations
