"""Check 3 — dead config knobs (DESIGN.md §15).

Every field of SearchConfig / IndexConfig / QuantConfig must be read
somewhere in src/ outside core/types.py. A knob nobody reads is worse
than missing: callers set it, tests sweep it, benchmarks report it — and
nothing changes (the `batch_B` bug, dead for two PRs before anyone
noticed the beam path ignored it).

Liveness is attribute-read based with property bridging: a field only
read by a property on its own class stays live iff that property (or a
property chain from it) is itself read externally — `max_hops` is live
through `hops_bound`, `pq_bits` through `nbits` -> `ksub`.

The serving-tier knob classes (SERVE_CLASSES: `Request`,
`DegradePolicy`, DESIGN.md §17) are covered allowlist-free under a
relaxed rule: a field is live if read ANYWHERE in src/ outside the lint
package, including the defining module — policy knobs like
`DegradePolicy.patience` are legitimately consumed by the class's own
methods, but a field nobody reads at all (a `deadline_ms` that admission
forgot to consult) still fails CI.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.common import (Tree, Violation, class_def,
                                   dataclass_fields, missing_file)

CHECK = "dead_knobs"
TYPES = "src/repro/core/types.py"
CLASSES = ("SearchConfig", "IndexConfig", "QuantConfig")
SERVE_CLASSES = (
    ("src/repro/serve/scheduler.py", ("Request",)),
    ("src/repro/serve/degrade.py", ("DegradePolicy",)),
)
ANALYSIS_PKG = "src/repro/analysis"


def _is_property(fn: ast.FunctionDef) -> bool:
    return any(isinstance(d, ast.Name) and d.id == "property"
               for d in fn.decorator_list)


def _self_reads(fn: ast.FunctionDef) -> Set[str]:
    """Attribute names read off `self` inside a method body."""
    out: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load) \
                and isinstance(n.value, ast.Name) and n.value.id == "self":
            out.add(n.attr)
    return out


def _attr_reads(tree: Tree, skip_module: Optional[str] = None) -> Set[str]:
    """Every attribute name read (Load context) anywhere in src/ outside
    the lint package itself and, when given, `skip_module`."""
    out: Set[str] = set()
    for rel in tree.iter_py("src"):
        if rel == skip_module or rel.startswith(ANALYSIS_PKG):
            continue
        mod = tree.parse(rel)
        if mod is None:
            continue
        for n in ast.walk(mod):
            if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load):
                out.add(n.attr)
    return out


def _external_attr_reads(tree: Tree) -> Set[str]:
    """The strict variant for the core config classes: reads in the
    defining module (core/types.py) do not count."""
    return _attr_reads(tree, skip_module=TYPES)


def _serve_violations(tree: Tree) -> List[Violation]:
    """Allowlist-free liveness for the serving knob classes, under the
    relaxed anywhere-in-src rule (module docstring). Fixture trees without
    a serving tier are skipped silently — absence of the module is the
    structure checks' concern, not a dead knob."""
    reads: Optional[Set[str]] = None
    violations: List[Violation] = []
    for rel, class_names in SERVE_CLASSES:
        mod = tree.parse(rel)
        if mod is None:
            continue
        if reads is None:
            reads = _attr_reads(tree)
        for cls_name in class_names:
            cls = class_def(mod, cls_name)
            if cls is None:
                violations.append(missing_file(
                    CHECK, rel, f"serving knob class {cls_name} not found"))
                continue
            for name, lineno in dataclass_fields(cls):
                if name not in reads:
                    violations.append(Violation(
                        CHECK, rel, lineno,
                        f"serving knob {cls_name}.{name} is never read "
                        f"anywhere in src/ (dead knob — set by callers, "
                        f"consulted by nothing)"))
    return violations


def run(tree: Tree) -> List[Violation]:
    types_mod = tree.parse(TYPES)
    if types_mod is None:
        return [missing_file(CHECK, TYPES, "config dataclasses live here")]

    ext = _external_attr_reads(tree)
    violations: List[Violation] = []
    for cls_name in CLASSES:
        cls = class_def(types_mod, cls_name)
        if cls is None:
            continue
        fields = dataclass_fields(cls)
        props: Dict[str, Set[str]] = {
            m.name: _self_reads(m) for m in cls.body
            if isinstance(m, ast.FunctionDef) and _is_property(m)}

        # Propagate liveness through property chains to a fixpoint:
        # externally-read names are live; anything a live property reads
        # becomes live too.
        live = {n for n, _ in fields if n in ext} | \
               {p for p in props if p in ext}
        changed = True
        while changed:
            changed = False
            for p, reads in props.items():
                if p in live and not reads.issubset(live):
                    live |= reads
                    changed = True

        for name, lineno in fields:
            if name not in live:
                violations.append(Violation(
                    CHECK, TYPES, lineno,
                    f"config knob {cls_name}.{name} is never read outside "
                    f"its defining module (dead knob — the batch_B bug "
                    f"class)"))
    violations.extend(_serve_violations(tree))
    return violations
