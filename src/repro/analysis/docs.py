"""Check 6 — DESIGN.md cross-reference integrity (docs_xref).

Every `DESIGN.md §N` citation anywhere in the tree must resolve to a
real `## §N` section header, and the numbered sections themselves must
be contiguous from §1 — inserting a section (e.g. §12 "Sharded search",
which shifted quantization to §13) forces every stale citation to fail
the lint instead of silently pointing at the wrong architecture note.

Grown out of tests/test_docs.py so the no-pip CI lint lane catches
dangling references without running pytest; the pytest side now just
delegates here.  Raw text scan (citations live in comments, docstrings
and markdown — the AST never sees most of them), same file scope as the
other checks: iter_py over the code trees + the top-level markdown
files.
"""
from __future__ import annotations

import re
from typing import List, Optional, Set

from repro.analysis.common import Tree, Violation, missing_file

CHECK = "docs_xref"
DESIGN = "DESIGN.md"

CITATION = re.compile(r"DESIGN\.md §(\d+)")
HEADER = re.compile(r"^## §(\d+)")
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
SCAN_FILES = ("README.md", "ROADMAP.md", "CHANGES.md")


def sections_of(tree: Tree) -> Optional[Set[int]]:
    """Numbered `## §N` headers of DESIGN.md; None when the file is
    missing (fixture trees / pre-docs checkouts)."""
    text = _read(tree, DESIGN)
    if text is None:
        return None
    return {int(m.group(1)) for line in text.splitlines()
            for m in [HEADER.match(line)] if m}


def _read(tree: Tree, rel: str) -> Optional[str]:
    try:
        return (tree.root / rel).read_text()
    except OSError:
        return None


def run(tree: Tree) -> List[Violation]:
    secs = sections_of(tree)
    if secs is None:
        return [missing_file(CHECK, DESIGN, "section headers live here")]
    violations: List[Violation] = []
    if not secs:
        violations.append(Violation(
            CHECK, DESIGN, 1, "no numbered `## §N` sections found"))
    elif secs != set(range(1, max(secs) + 1)):
        missing = sorted(set(range(1, max(secs) + 1)) - secs)
        violations.append(Violation(
            CHECK, DESIGN, 1,
            f"numbered sections must be contiguous from §1: "
            f"§{', §'.join(str(s) for s in missing)} missing "
            f"(present: {sorted(secs)})"))

    scan = list(tree.iter_py(*SCAN_DIRS))
    scan += [f for f in SCAN_FILES if tree.exists(f)]
    for rel in scan:
        src = _read(tree, rel)
        if src is None:
            continue
        for lineno, line in enumerate(src.splitlines(), start=1):
            for n in CITATION.findall(line):
                if int(n) not in secs:
                    violations.append(Violation(
                        CHECK, rel, lineno,
                        f"citation 'DESIGN.md §{n}' does not resolve to "
                        f"any `## §{n}` header"))
    return violations
