"""Check 5 — static VMEM budget estimator (DESIGN.md §15).

For every Pallas kernel wrapper, sum the bytes its BlockSpec blocks and
pltpu.VMEM scratch shapes pin in VMEM at one grid step, and assert the
total stays under a per-kernel budget. DESIGN.md argues throughout that
LUTs and tiles "stay VMEM resident" — this check does the arithmetic,
so a BlockSpec edit that silently blows the ~16 MiB/core budget (and
would spill to HBM on hardware) fails CI instead of shipping.

Shape expressions inside BlockSpec/VMEM calls are symbolic (d, tq, m,
K, C, ...). They are evaluated against representative worst-case
bindings (DIMS below — the largest values the presets/benchmarks use);
names the evaluator cannot resolve fall back to DEFAULT_DIM and are
called out in the report. In/out blocks are counted twice (the pipeline
double-buffers them: step i+1's DMA lands while step i computes);
scratch is single-buffered. Element size is 4 bytes unless the scratch
dtype says otherwise — conservative for the u8/u32 code blocks.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.common import Tree, Violation, calls_to, keyword_arg, \
    top_level_functions
from repro.analysis.parity import find_kernels

CHECK = "vmem_budget"
KERNELS_DIR = "src/repro/kernels"

# Representative worst-case dimension bindings: tile sizes from the
# wrappers' own clamps, m/K/C/T/L/max_len from the largest preset and
# benchmark configs in the tree.
DIMS: Dict[str, int] = {
    "d": 1024, "tq": 128, "tb": 128, "m": 64, "K": 256, "mh": 32,
    "C": 4096, "T": 1024, "W": 16, "n_beam": 16, "L": 1024,
    "max_len": 4096, "nw": 64, "Q": 8, "B": 8, "P": 8, "nlist": 64,
}
DEFAULT_DIM = 128
DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "float64": 8,
               "int64": 8, "bfloat16": 2, "float16": 2, "uint8": 1,
               "int8": 1, "bool_": 1}
ELEM_BYTES = 4

DEFAULT_BUDGET = 16 * 1024 * 1024          # ~VMEM per TensorCore
# Per-kernel overrides would go here, keyed by wrapper name.
BUDGETS: Dict[str, int] = {}


@dataclasses.dataclass
class KernelEstimate:
    name: str
    path: str
    line: int
    n_blocks: int
    block_bytes: int       # sum over BlockSpec blocks, single-buffered
    scratch_bytes: int
    notes: List[str]

    @property
    def total_bytes(self) -> int:
        return 2 * self.block_bytes + self.scratch_bytes


def _eval_dim(node: ast.expr, notes: List[str]) -> int:
    expr = ast.unparse(node)
    try:
        val = eval(compile(ast.Expression(body=node), "<dim>", "eval"),
                   {"__builtins__": {}}, dict(DIMS))
        return max(int(val), 1)
    except Exception:
        notes.append(f"unresolved dim '{expr}' -> {DEFAULT_DIM}")
        return DEFAULT_DIM


def _shape_elems(node: Optional[ast.expr], notes: List[str]) -> int:
    if not isinstance(node, (ast.Tuple, ast.List)):
        if node is not None:
            notes.append(f"non-literal shape '{ast.unparse(node)}' skipped")
        return 0
    elems = 1
    for e in node.elts:
        elems *= _eval_dim(e, notes)
    return elems


def _scratch_bytes(call: ast.Call, notes: List[str]) -> int:
    shape = call.args[0] if call.args else keyword_arg(call, "shape")
    elems = _shape_elems(shape, notes)
    nbytes = ELEM_BYTES
    dt = call.args[1] if len(call.args) > 1 else keyword_arg(call, "dtype")
    if isinstance(dt, ast.Attribute) and dt.attr in DTYPE_BYTES:
        nbytes = DTYPE_BYTES[dt.attr]
    return elems * nbytes


def estimate(tree: Tree) -> List[KernelEstimate]:
    out: List[KernelEstimate] = []
    for rel, name, lineno in find_kernels(tree):
        mod = tree.parse(rel)
        fns = top_level_functions(mod)
        fn = fns[name]
        notes: List[str] = []

        # BlockSpecs appear inline in the wrapper, or behind module-level
        # helpers the wrapper calls (traverse_step's _out_specs(T, W)).
        spec_scopes = [fn]
        for call in ast.walk(fn):
            if isinstance(call, ast.Call) and \
                    isinstance(call.func, ast.Name) and \
                    call.func.id in fns and call.func.id != name:
                helper = fns[call.func.id]
                if any(True for _ in calls_to(helper, "BlockSpec")):
                    spec_scopes.append(helper)

        block_bytes = 0
        n_blocks = 0
        for scope in spec_scopes:
            for spec in calls_to(scope, "BlockSpec"):
                shape = spec.args[0] if spec.args else \
                    keyword_arg(spec, "block_shape")
                elems = _shape_elems(shape, notes)
                if elems:
                    n_blocks += 1
                    block_bytes += elems * ELEM_BYTES
        scratch_bytes = sum(_scratch_bytes(c, notes)
                            for c in calls_to(fn, "VMEM"))
        out.append(KernelEstimate(name, rel, lineno, n_blocks,
                                  block_bytes, scratch_bytes, notes))
    return out


def run(tree: Tree) -> List[Violation]:
    violations: List[Violation] = []
    for est in estimate(tree):
        budget = BUDGETS.get(est.name, DEFAULT_BUDGET)
        if est.total_bytes > budget:
            violations.append(Violation(
                CHECK, est.path, est.line,
                f"kernel '{est.name}' estimated VMEM residency "
                f"{est.total_bytes / 2**20:.1f} MiB "
                f"(2x{est.block_bytes / 2**20:.1f} blocks + "
                f"{est.scratch_bytes / 2**20:.1f} scratch) exceeds its "
                f"{budget / 2**20:.0f} MiB budget"))
        if est.n_blocks == 0:
            violations.append(Violation(
                CHECK, est.path, est.line,
                f"kernel '{est.name}' has no resolvable BlockSpec shapes "
                f"— the VMEM estimate would be vacuous"))
    return violations


def report(tree: Tree) -> str:
    """The --report table: per-kernel VMEM residency breakdown."""
    rows = [f"{'kernel':<18} {'blocks':>6} {'block KiB':>10} "
            f"{'scratch KiB':>12} {'est KiB':>8} {'budget':>7}  notes"]
    for est in estimate(tree):
        budget = BUDGETS.get(est.name, DEFAULT_BUDGET)
        rows.append(
            f"{est.name:<18} {est.n_blocks:>6} "
            f"{est.block_bytes / 1024:>10.1f} "
            f"{est.scratch_bytes / 1024:>12.1f} "
            f"{est.total_bytes / 1024:>8.1f} "
            f"{budget / 2**20:>6.0f}M  {'; '.join(est.notes)}")
    return "\n".join(rows)
