"""Check 7 — static per-kernel / per-query cost model (DESIGN.md §16).

Extends the vmem_budget symbolic machinery from "how many bytes does one
grid step pin in VMEM" to "what does a whole call — and a whole query —
cost": closed-form FLOPs, HBM bytes moved, and distance evaluations per
kernel call, as functions of the workload parameters (n, d, L, beam W,
graph degree M, pq m, nprobe, quant kind). Two layers:

  1. KERNEL_COSTS — a registry of closed-form expressions per public
     Pallas kernel (the same 14-kernel surface parity.find_kernels
     discovers).  The FLOP terms model the code as written — e.g. the
     ADC gather-as-matmul really spends m*K MACs per code on the MXU,
     not m table reads, which is exactly why pq4 (K=16) beats pq8
     (K=256) on compute — and the byte terms are dtype-aware (u8 codes,
     u32 sign words, f32 everything else).
  2. AST extraction — the kernel's grid (pallas_call / GridSpec
     `grid=`) and BlockSpec shapes are parsed and evaluated against the
     workload bindings, giving grid-step counts and a per-call DMA
     upper bound, plus the vmem_budget residency reuse.  A kernel whose
     grid or formula does not resolve is a violation: the cost report
     must never silently skip a kernel (`python -m repro.analysis
     --check cost` exits 1 on the seeded `mystery_scan` fixture).

On top sit the per-query composition formulas used by the roofline
benchmark and core/tune.py's model-guided pruning:

  graph:  seed-dist cost + ceil(hops/W) x fused-expand cost + rerank
  ivf:    coarse probe (Q x nlist) + nprobe x padded list scan + rerank

and the EXACT distance-count terms the roofline smoke lane asserts
against measured SearchStats.n_dist (seed / rerank / scanned-list
arithmetic mirrors core/index.py's accounting — see ivf_n_dist_exact).

Pure stdlib like the rest of the package: the model never imports the
code it prices.
"""
from __future__ import annotations

import ast
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.analysis.common import Tree, Violation, calls_to, keyword_arg, \
    top_level_functions
from repro.analysis.parity import find_kernels
from repro.analysis import vmem

CHECK = "cost"

# Roofline constants for the paper's target part (Kunpeng 920-class
# socket: 48 cores x 2.6 GHz x 2 NEON pipes x 4 f32 lanes ~ 1 Tf32/s;
# 8-channel DDR4-2933 ~ 190 GB/s).  Only ORDERING between configs is
# asserted anywhere (roofline --smoke Spearman), never absolute time.
PEAK_FLOPS = 1.0e12
MEM_BW = 190e9

# Traversal-length heuristic: lockstep best-first converges after ~1.1*L
# expansions with early termination (BENCH_traverse.json: 71 iterations
# at L=64, W=1) and runs meaningfully longer without it.
HOPS_PER_L_ET = 1.15
HOPS_PER_L_NO_ET = 1.75
# Fraction of gathered neighbors surviving dedupe/visited masks — only
# used for EXPECTED traversal cost, never for the exact n_dist checks.
TRAVERSAL_YIELD = 0.8

LANE = 128


@dataclasses.dataclass(frozen=True)
class Workload:
    """The knob vector every closed-form expression is evaluated at.
    Mirrors IndexConfig/SearchConfig without importing them (the lint
    package stays stdlib-only); build one from live configs with
    `workload_from`."""

    n: int = 50_000          # corpus size
    d: int = 128             # vector dim (pre lane-padding)
    Q: int = 8               # queries per batch
    k: int = 10              # results returned
    L: int = 192             # candidate queue / scan depth
    M: int = 32              # graph out-degree
    W: int = 4               # beam width
    m: int = 16              # PQ subspaces
    kind: str = "pq"         # quant kind (types.QUANT_KINDS)
    index_type: str = "graph"
    nprobe: int = 32
    nlist: int = 0           # 0 => round(sqrt(n)) like IVFConfig
    list_pad: int = 128
    n_entries: int = 8
    rescore_factor: int = 32
    rerank: int = 0          # explicit exact-rerank depth (0 => derived)
    early_term: bool = True


DEFAULT_WORKLOAD = Workload()


def workload_from(config, search=None, n: int = 0, Q: int = 1) -> Workload:
    """Duck-typed bridge from a live IndexConfig (+ optional SearchConfig
    override) — keeps core/ free to import nothing from here and vice
    versa."""
    s = search if search is not None else config.search
    return Workload(
        n=n or DEFAULT_WORKLOAD.n, d=config.dim, Q=Q, k=s.k, L=s.L,
        M=config.build.M, W=s.beam_width, m=config.quant.pq_m,
        kind=config.quant.kind, index_type=config.index_type,
        nprobe=s.nprobe, nlist=config.ivf.nlist,
        list_pad=config.ivf.list_pad, n_entries=s.n_entries,
        rescore_factor=s.rescore_factor, rerank=config.quant.rerank,
        early_term=s.early_term)


# ------------------------------------------------------- symbol bindings

def _auto_nlist(n: int) -> int:
    return max(2, min(n, int(round(math.sqrt(n)))))


def _pad_to(x: int, mult: int) -> int:
    return max(1, -(-x // mult)) * mult


def _lg(x) -> float:
    return max(1.0, math.log2(max(float(x), 2.0)))


def bindings(w: Workload, **over) -> Dict[str, object]:
    """Evaluation namespace for KERNEL_COSTS expressions AND for the
    AST-extracted grid/BlockSpec dims (superset of vmem.DIMS names).
    `over` pins call-site-specific symbols (C for a rerank of r
    candidates, P/max_len from a real built index, ...)."""
    nlist = w.nlist if w.nlist > 0 else _auto_nlist(w.n)
    nlist = min(nlist, w.n)
    fill = w.n / nlist
    ns: Dict[str, object] = {
        "n": w.n, "d": w.d, "D": _pad_to(w.d, LANE), "Q": w.Q, "k": w.k,
        "L": w.L, "T": w.L, "M": w.M, "W": w.W, "n_beam": w.W,
        "C": w.W * w.M, "m": w.m, "K": 256, "K4": 16, "mh": 32,
        "nw": -(-w.d // 32), "tq": 128, "tb": 128, "B": 4096,
        "nlist": nlist, "fill": fill,
        "max_len": _pad_to(int(math.ceil(fill)), w.list_pad),
        "P": min(w.nprobe, nlist),
        "lg": _lg,
    }
    ns.update(over)
    return ns


def _eval_expr(expr: str, ns: Dict[str, object]) -> float:
    val = eval(compile(expr, "<cost>", "eval"), {"__builtins__": {}}, ns)
    return float(val)


# --------------------------------------------- closed-form kernel models

@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Per-CALL closed forms (DESIGN.md §16 derives each family)."""
    flops: str       # arithmetic executed (padded lanes included)
    hbm_bytes: str   # dtype-aware bytes moved HBM<->VMEM
    cands: str       # distance evaluations the call contributes to n_dist
    note: str = ""


# The merge term of the fused traversal kernels: a bitonic-style sort of
# the (queue + candidates) region costs ~x*lg(x)^2 compare-exchanges.
_SORT = "(L + C) * lg(L + C)**2"
# The per-list partial top-L of the IVF scans.
_TOPL = "max_len * lg(L)"

KERNEL_COSTS: Dict[str, KernelCost] = {
    # -- plain distance kernels ------------------------------------------
    "batch_dist": KernelCost(
        flops="3.0*Q*B*D",
        hbm_bytes="4.0*(Q*B*D/tb + Q*B*D/tq + Q*B)",
        cands="Q*B",
        note="tiled (tq x tb) matmul lift; both operands re-stream per tile"),
    "gather_dist": KernelCost(
        flops="3.0*Q*C*D",
        hbm_bytes="4.0*(Q*C*D + Q*D + 2.0*Q*C)",
        cands="Q*C",
        note="one gathered f32 row DMA per candidate dominates"),
    "sq_gather_dist": KernelCost(
        flops="5.0*Q*C*D",
        hbm_bytes="1.0*Q*C*D + 4.0*(Q*D + 2.0*D + 2.0*Q*C)",
        cands="Q*C",
        note="u8 rows: 4x less traffic than gather_dist, +2 dequant ops/dim"),
    "bin_dist": KernelCost(
        flops="4.0*Q*C*nw",
        hbm_bytes="4.0*(Q*C*nw + Q*nw + 2.0*Q*C)",
        cands="Q*C",
        note="XOR + SWAR popcount per u32 word; nw = ceil(d/32) words"),
    # -- ADC kernels (gather-as-matmul: m*K MACs per code, DESIGN.md §13) -
    "pq_adc": KernelCost(
        flops="3.0*Q*C*m*K",
        hbm_bytes="1.0*Q*C*m + 4.0*(Q*m*K + 2.0*Q*C)",
        cands="Q*C",
        note="one-hot MXU expansion: K=256 MACs per code, not a table read"),
    "pq4_adc": KernelCost(
        flops="3.0*Q*C*m*K4",
        hbm_bytes="0.5*Q*C*m + 4.0*(Q*m*K4 + 2.0*Q*C)",
        cands="Q*C",
        note="K=16 one-hot + nibble-packed codes: 16x fewer MACs than pq8"),
    # -- fused beam-expansion kernels (gather+dist+merge, DESIGN.md §2) ---
    "fused_expand": KernelCost(
        flops="Q*(3.0*C*D + %s)" % _SORT,
        hbm_bytes="4.0*Q*(C*D + D + 4.0*(L + C))",
        cands="Q*C"),
    "fused_expand_sq": KernelCost(
        flops="Q*(5.0*C*D + %s)" % _SORT,
        hbm_bytes="Q*(1.0*C*D + 4.0*D + 16.0*(L + C))",
        cands="Q*C"),
    "fused_expand_pq": KernelCost(
        flops="Q*(3.0*C*m*K + %s)" % _SORT,
        hbm_bytes="Q*(1.0*C*m + 4.0*m*K + 16.0*(L + C))",
        cands="Q*C"),
    "fused_expand_pq4": KernelCost(
        flops="Q*(3.0*C*m*K4 + %s)" % _SORT,
        hbm_bytes="Q*(0.5*C*m + 4.0*m*K4 + 16.0*(L + C))",
        cands="Q*C"),
    "fused_expand_bin": KernelCost(
        flops="Q*(4.0*C*nw + %s)" % _SORT,
        hbm_bytes="Q*(4.0*C*nw + 4.0*nw + 16.0*(L + C))",
        cands="Q*C"),
    # -- IVF padded-list scans (DESIGN.md §4) -----------------------------
    "ivf_scan": KernelCost(
        flops="Q*P*(3.0*max_len*m*K + %s)" % _TOPL,
        hbm_bytes="Q*P*(1.0*max_len*m + 4.0*max_len + 4.0*m*K + 8.0*L)",
        cands="Q*P*max_len",
        note="scans PADDED lists; n_dist counts only the valid entries"),
    "pq4_ivf_scan": KernelCost(
        flops="Q*P*(3.0*max_len*m*K4 + %s)" % _TOPL,
        hbm_bytes="Q*P*(0.5*max_len*m + 4.0*max_len + 4.0*m*K4 + 8.0*L)",
        cands="Q*P*max_len"),
    "bin_ivf_scan": KernelCost(
        flops="Q*P*(4.0*max_len*nw + %s)" % _TOPL,
        hbm_bytes="Q*P*(4.0*max_len*nw + 4.0*max_len + 8.0*L) + 4.0*Q*nw",
        cands="Q*P*max_len"),
}


def kernel_cost(name: str, w: Workload, **over) -> Tuple[float, float, float]:
    """(flops, hbm_bytes, cands) for one call of `name` under `w`, with
    `over` pinning call-site symbols (e.g. C=rerank_depth)."""
    kc = KERNEL_COSTS[name]
    ns = bindings(w, **over)
    return (_eval_expr(kc.flops, ns), _eval_expr(kc.hbm_bytes, ns),
            _eval_expr(kc.cands, ns))


# ----------------------------------------------------- AST grid extraction

_GRID_CARRIERS = ("PrefetchScalarGridSpec", "GridSpec", "pallas_call")


def _grid_node(fn: ast.FunctionDef, fns: Dict[str, ast.FunctionDef]
               ) -> Optional[ast.expr]:
    """The `grid=` expression of the wrapper's pallas_call / grid spec,
    searching the same helper scopes vmem does."""
    scopes = [fn]
    for call in ast.walk(fn):
        if isinstance(call, ast.Call) and isinstance(call.func, ast.Name) \
                and call.func.id in fns and call.func.id != fn.name:
            scopes.append(fns[call.func.id])
    for scope in scopes:
        for carrier in _GRID_CARRIERS:
            for call in calls_to(scope, carrier):
                g = keyword_arg(call, "grid")
                if g is not None:
                    return g
    return None


def _eval_dims(node: ast.expr, ns: Dict[str, object], notes: List[str]
               ) -> int:
    """Product of a grid/shape tuple's dims under `ns`; 0 + a note when a
    dim does not resolve (which run() turns into a violation — unlike
    vmem's forgiving DEFAULT_DIM fallback, an unresolvable cost is an
    error: the whole point is a closed form)."""
    elts = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    prod = 1
    for e in elts:
        try:
            val = eval(compile(ast.Expression(body=e), "<dim>", "eval"),
                       {"__builtins__": {}}, dict(ns))
            prod *= max(int(val), 1)
        except Exception:
            notes.append(f"unresolved dim '{ast.unparse(e)}'")
            return 0
    return prod


@dataclasses.dataclass
class CostEstimate:
    """Per-kernel row of the cost report."""
    name: str
    path: str
    line: int
    flops: float           # closed-form, per call at the bound workload
    hbm_bytes: float
    cands: float
    grid_steps: int        # AST-extracted grid product
    dma_bytes: int         # grid_steps x sum(BlockSpec block bytes)
    vmem_bytes: int        # vmem_budget residency reuse
    notes: List[str]

    @property
    def intensity(self) -> float:
        return self.flops / self.hbm_bytes if self.hbm_bytes else 0.0


def estimate(tree: Tree, w: Workload = DEFAULT_WORKLOAD
             ) -> List[CostEstimate]:
    """One row per discovered kernel; never raises — unresolvable pieces
    land in .notes (run() promotes them to violations)."""
    ns = bindings(w)
    vmem_by_name = {e.name: e for e in vmem.estimate(tree)}
    out: List[CostEstimate] = []
    for rel, name, lineno in find_kernels(tree):
        notes: List[str] = []
        flops = hbm = cands = 0.0
        if name in KERNEL_COSTS:
            try:
                flops, hbm, cands = kernel_cost(name, w)
            except Exception as e:
                notes.append(f"formula failed: {e!r}")
        else:
            notes.append("no closed-form cost formula in KERNEL_COSTS")

        mod = tree.parse(rel)
        fns = top_level_functions(mod) if mod else {}
        grid_steps = 0
        dma = 0
        fn = fns.get(name)
        gnode = _grid_node(fn, fns) if fn is not None else None
        if gnode is None:
            notes.append("no resolvable grid= on the pallas_call/grid spec")
        else:
            grid_steps = _eval_dims(gnode, ns, notes)
        ve = vmem_by_name.get(name)
        if ve is not None and grid_steps:
            dma = grid_steps * ve.block_bytes
        out.append(CostEstimate(name, rel, lineno, flops, hbm, cands,
                                grid_steps, dma,
                                ve.total_bytes if ve else 0, notes))
    return out


# ------------------------------------------------- per-query composition

def wide_L(w: Workload) -> int:
    """The widened queue the quantized first pass actually runs with
    (core/index.py _widen/_widen_bin)."""
    if w.kind == "none":
        return w.L
    if w.kind == "bin":
        return max(w.L, w.rescore_factor * w.k)
    return max(w.L, 4 * w.k)


def graph_rerank_depth(w: Workload) -> int:
    """Exact-rerank distances per query on the graph path, assuming the
    widened queue fills (it does beyond toy corpora; the roofline lane's
    rerank-delta check validates saturation)."""
    if w.kind == "none":
        return 0
    wl = wide_L(w)
    if w.kind == "bin":
        r = w.rerank if w.rerank > 0 else w.rescore_factor * w.k
    else:
        r = w.rerank if w.rerank > 0 else min(4 * w.k, wl)
    return min(max(r, w.k), wl)


def ivf_geometry(w: Workload, nlist: int = 0, max_len: int = 0
                 ) -> Tuple[int, float, int, int, int, int]:
    """(nlist, fill, max_len, P, Lp, cand_width) — pass the REAL nlist /
    max_len of a built index for exact arithmetic; defaults assume
    balanced lists."""
    nl = nlist or min(w.nlist if w.nlist > 0 else _auto_nlist(w.n), w.n)
    fill = w.n / nl
    ml = max_len or _pad_to(int(math.ceil(fill)), w.list_pad)
    P = min(w.nprobe, nl)
    wl = wide_L(w)
    Lp = min(wl, ml)
    return nl, fill, ml, P, Lp, min(wl, P * Lp)


def ivf_rerank_depth(w: Workload, nlist: int = 0, max_len: int = 0) -> int:
    """rr resolved the way core/index.py does for the IVF path (bin uses
    the explicit rescore_factor*k overfetch, others default to the whole
    candidate queue)."""
    _, _, _, _, _, width = ivf_geometry(w, nlist, max_len)
    if w.kind == "bin" and w.rerank == 0:
        r = w.rescore_factor * w.k
    else:
        r = w.rerank if w.rerank > 0 else width
    return min(max(r, w.k), width)


def ivf_n_dist_exact(w: Workload, scanned: int, nlist: int = 0,
                     max_len: int = 0) -> int:
    """EXACT per-query SearchStats.n_dist for the IVF path: valid codes
    scanned across the probed lists + the exact-rerank term, where the
    rerank only counts candidates that exist (min with `scanned` and the
    merged queue width).  `scanned` comes from the built index + probe
    assignment (ivf.scanned_counts), NOT from search stats — the check
    in benchmarks/roofline.py is non-circular."""
    _, _, _, _, _, width = ivf_geometry(w, nlist, max_len)
    r = ivf_rerank_depth(w, nlist, max_len)
    return int(scanned) + min(r, width, int(scanned))


def est_hops(w: Workload) -> int:
    """Expected traversal expansions (nodes popped) per query — the
    calibratable heuristic behind EXPECTED cost; exact checks never use
    it."""
    per_l = HOPS_PER_L_ET if w.early_term else HOPS_PER_L_NO_ET
    return max(1, int(round(per_l * wide_L(w))))


_GRAPH_DIST_KERNEL = {"none": "gather_dist", "sq": "sq_gather_dist",
                      "pq": "pq_adc", "pq4": "pq4_adc", "bin": "bin_dist"}
_GRAPH_EXPAND_KERNEL = {"none": "fused_expand", "sq": "fused_expand_sq",
                        "pq": "fused_expand_pq", "pq4": "fused_expand_pq4",
                        "bin": "fused_expand_bin"}
_IVF_SCAN_KERNEL = {"pq": "ivf_scan", "pq4": "pq4_ivf_scan",
                    "bin": "bin_ivf_scan", "none": "ivf_scan",
                    "sq": "ivf_scan"}


@dataclasses.dataclass(frozen=True)
class QueryCost:
    """Composed cost of one search batch (w.Q queries)."""
    Q: int
    flops: float
    hbm_bytes: float
    n_dist: float                 # expected distance evals PER QUERY
    breakdown: Tuple[Tuple[str, float, float, float], ...]
    # (kernel, calls, flops, bytes) per stage

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / MEM_BW

    @property
    def seconds(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def dominant(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"

    @property
    def us_per_query(self) -> float:
        return self.seconds / max(self.Q, 1) * 1e6


def graph_search_cost(w: Workload, hops: Optional[int] = None) -> QueryCost:
    """seed dists + ceil(hops/W) fused-expand iterations + exact rerank."""
    h = hops if hops is not None else est_hops(w)
    iters = max(1, -(-h // max(w.W, 1)))
    wl = wide_L(w)
    parts: List[Tuple[str, float, float, float]] = []

    seed_k = _GRAPH_DIST_KERNEL[w.kind]
    f, b, _ = kernel_cost(seed_k, w, C=max(w.n_entries, 1), L=wl)
    parts.append((seed_k + ":seed", 1, f, b))

    exp_k = _GRAPH_EXPAND_KERNEL[w.kind]
    f, b, _ = kernel_cost(exp_k, w, C=w.W * w.M, L=wl)
    parts.append((exp_k, iters, f * iters, b * iters))

    r = graph_rerank_depth(w)
    if r:
        f, b, _ = kernel_cost("gather_dist", w, C=r)
        parts.append(("gather_dist:rerank", 1, f, b))

    n_dist = (w.n_entries + h * w.M * TRAVERSAL_YIELD + r)
    return QueryCost(w.Q, sum(p[2] for p in parts), sum(p[3] for p in parts),
                     n_dist, tuple(parts))


def ivf_search_cost(w: Workload, nlist: int = 0, max_len: int = 0
                    ) -> QueryCost:
    """coarse probe (Q x nlist batch_dist) + padded list scan + rerank."""
    nl, fill, ml, P, Lp, width = ivf_geometry(w, nlist, max_len)
    parts: List[Tuple[str, float, float, float]] = []

    f, b, _ = kernel_cost("batch_dist", w, B=max(nl, 1))
    parts.append(("batch_dist:probe", 1, f, b))

    scan_k = _IVF_SCAN_KERNEL[w.kind]
    f, b, _ = kernel_cost(scan_k, w, P=P, max_len=ml, L=Lp, nlist=nl)
    parts.append((scan_k, 1, f, b))

    r = ivf_rerank_depth(w, nlist, max_len)
    f, b, _ = kernel_cost("gather_dist", w, C=r)
    parts.append(("gather_dist:rerank", 1, f, b))

    exp_scanned = P * fill
    n_dist = exp_scanned + min(r, width, exp_scanned)
    return QueryCost(w.Q, sum(p[2] for p in parts), sum(p[3] for p in parts),
                     n_dist, tuple(parts))


def search_cost(w: Workload, **kw) -> QueryCost:
    return (ivf_search_cost(w, **kw) if w.index_type == "ivf"
            else graph_search_cost(w, **kw))


def predict_service_s(config, search=None, Q: int = 1, n: int = 0) -> float:
    """Latency-predictor hook for the serving tier (DESIGN.md §17):
    predicted seconds for ONE dispatched batch of Q queries under
    (config, search). Absolute scale assumes the Kunpeng roofline
    constants; serve.degrade.LatencyModel multiplies in an EWMA-calibrated
    measured/predicted ratio, so only the RELATIVE ordering across
    (SearchConfig, bucket) keys is load-bearing here — the ordering the
    roofline bench validates (Spearman rho vs live runs)."""
    return search_cost(workload_from(config, search, n=n, Q=Q)).seconds


# --------------------------------------------------------- check + report

def run(tree: Tree) -> List[Violation]:
    violations: List[Violation] = []
    found = set()
    for est in estimate(tree):
        found.add(est.name)
        for note in est.notes:
            violations.append(Violation(
                CHECK, est.path, est.line,
                f"kernel '{est.name}' has no resolvable closed-form cost "
                f"({note}) — add a KERNEL_COSTS entry / fix the symbols "
                f"so the model covers the whole kernel surface"))
        if not est.notes and (est.flops <= 0 or est.hbm_bytes <= 0
                              or est.cands < 0):
            violations.append(Violation(
                CHECK, est.path, est.line,
                f"kernel '{est.name}' cost evaluates non-positive "
                f"(flops={est.flops}, bytes={est.hbm_bytes})"))
    # stale registry entries — only meaningful when the tree carries the
    # real kernel surface (fixture trees hold a single alien kernel)
    if found & set(KERNEL_COSTS):
        for name in sorted(set(KERNEL_COSTS) - found):
            violations.append(Violation(
                CHECK, "src/repro/analysis/cost.py", 1,
                f"KERNEL_COSTS entry '{name}' matches no discovered "
                f"kernel (stale formula)"))
    return violations


_QUERY_ROWS = (("graph", "none"), ("graph", "sq"), ("graph", "pq"),
               ("graph", "pq4"), ("graph", "bin"),
               ("ivf", "pq"), ("ivf", "pq4"), ("ivf", "bin"))


def _query_table(w: Workload) -> List[dict]:
    rows = []
    for index_type, kind in _QUERY_ROWS:
        wk = dataclasses.replace(w, index_type=index_type, kind=kind)
        qc = search_cost(wk)
        rows.append({"config": f"{index_type}/{kind}",
                     "n_dist": qc.n_dist,
                     "flops": qc.flops, "hbm_bytes": qc.hbm_bytes,
                     "t_compute": qc.t_compute, "t_memory": qc.t_memory,
                     "dominant": qc.dominant,
                     "us_per_query": qc.us_per_query})
    return rows


def cost_model(tree: Tree, w: Workload = DEFAULT_WORKLOAD) -> dict:
    """Machine-readable model dump (--json, CI artifact)."""
    return {
        "workload": dataclasses.asdict(w),
        "constants": {"peak_flops": PEAK_FLOPS, "mem_bw": MEM_BW},
        "kernels": [dataclasses.asdict(e) for e in estimate(tree, w)],
        "queries": _query_table(w),
    }


def report(tree: Tree, w: Workload = DEFAULT_WORKLOAD) -> str:
    """--report table: per-kernel closed forms + per-query composition."""
    rows = [f"{'kernel':<18} {'GFLOP/call':>11} {'MB/call':>9} "
            f"{'F/B':>6} {'grid':>7} {'dma MB':>8}  notes"]
    for e in estimate(tree, w):
        rows.append(f"{e.name:<18} {e.flops / 1e9:>11.3f} "
                    f"{e.hbm_bytes / 1e6:>9.2f} {e.intensity:>6.1f} "
                    f"{e.grid_steps:>7} {e.dma_bytes / 1e6:>8.2f}  "
                    f"{'; '.join(e.notes)}")
    rows.append("")
    rows.append(f"per-query composition at n={w.n} d={w.d} L={w.L} "
                f"W={w.W} nprobe={w.nprobe} (Q={w.Q}):")
    rows.append(f"{'config':<12} {'n_dist':>8} {'GFLOP':>8} {'MB':>8} "
                f"{'us/q':>8}  bound")
    for r in _query_table(w):
        rows.append(f"{r['config']:<12} {r['n_dist']:>8.0f} "
                    f"{r['flops'] / 1e9:>8.3f} "
                    f"{r['hbm_bytes'] / 1e6:>8.2f} "
                    f"{r['us_per_query']:>8.1f}  {r['dominant']}")
    return "\n".join(rows)
