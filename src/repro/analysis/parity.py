"""Check 1 — kernel/ref/dispatch parity (DESIGN.md §15).

Every Pallas kernel exported from kernels/*.py must come as a triple:
the kernel wrapper itself, a `<name>_ref` jnp oracle in kernels/ref.py,
and a `<name>` dispatch entry in kernels/ops.py — plus at least one test
under tests/ that references BOTH names (the kernel-vs-ref parity test).

This pins the `("sq", "kernel")` cache-key bug class: a kernel path that
exists but has no oracle (or no test comparing the two) can silently lie
about which impl actually ran.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import List, Tuple

from repro.analysis.common import (Tree, Violation, calls_to, missing_file,
                                   referenced_names, top_level_functions)

CHECK = "kernel_parity"
KERNELS_DIR = "src/repro/kernels"
REF = "src/repro/kernels/ref.py"
OPS = "src/repro/kernels/ops.py"
NON_KERNEL_FILES = {"__init__.py", "ops.py", "ref.py"}


def find_kernels(tree: Tree) -> List[Tuple[str, str, int]]:
    """(module_rel, name, lineno) for every public top-level function in
    kernels/*.py whose body reaches pallas_call."""
    out = []
    for rel in tree.iter_py(KERNELS_DIR):
        if PurePosixPath(rel).name in NON_KERNEL_FILES:
            continue
        mod = tree.parse(rel)
        if mod is None:
            continue
        for fn in top_level_functions(mod).values():
            if fn.name.startswith("_"):
                continue
            if any(True for _ in calls_to(fn, "pallas_call")):
                out.append((rel, fn.name, fn.lineno))
    return out


def run(tree: Tree) -> List[Violation]:
    violations: List[Violation] = []
    kernels = find_kernels(tree)

    ref_mod = tree.parse(REF)
    ops_mod = tree.parse(OPS)
    ref_names = set(top_level_functions(ref_mod)) if ref_mod else set()
    ops_names = set(top_level_functions(ops_mod)) if ops_mod else set()
    if kernels and ref_mod is None:
        violations.append(missing_file(CHECK, REF, "jnp oracles live here"))
    if kernels and ops_mod is None:
        violations.append(missing_file(CHECK, OPS, "dispatch entries live here"))

    test_refs = []
    for rel in tree.iter_py("tests"):
        mod = tree.parse(rel)
        if mod is not None:
            test_refs.append(referenced_names(mod))

    for rel, name, lineno in kernels:
        oracle = name + "_ref"
        if ref_mod is not None and oracle not in ref_names:
            violations.append(Violation(
                CHECK, rel, lineno,
                f"Pallas kernel '{name}' has no jnp oracle '{oracle}' in "
                f"kernels/ref.py"))
        if ops_mod is not None and name not in ops_names:
            violations.append(Violation(
                CHECK, rel, lineno,
                f"Pallas kernel '{name}' has no dispatch entry "
                f"'def {name}' in kernels/ops.py"))
        if not any(name in refs and oracle in refs for refs in test_refs):
            violations.append(Violation(
                CHECK, rel, lineno,
                f"no parity test under tests/ references both '{name}' "
                f"and '{oracle}' (kernel-vs-ref comparison missing)"))
    return violations
