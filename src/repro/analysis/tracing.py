"""Check 4 — tracing safety (DESIGN.md §15).

Python-level control flow on traced values is the classic jax footgun:
`if x > 0`, `assert x.sum() == 1`, `float(x)` or `x.item()` inside a
jitted function either crash at trace time (TracerBoolConversionError)
or silently concretize and bake one value into the compiled program.
Inside a Pallas kernel body the same constructs freeze one grid step's
data into every step.

Scope (AST-only approximation of "jit-reachable"):
  A. Pallas kernel bodies — any function in kernels/*.py with a
     parameter ending in `_ref` (the Ref-passing convention), nested
     factory-made kernels included.
  B. jit entry points — top-level functions in core/*.py and
     kernels/*.py decorated with `jax.jit` or
     `functools.partial(jax.jit, static_argnames=...)`; every
     non-static parameter is traced, and nested def/lambda parameters
     (scan/cond bodies, index maps) are traced too.

Taint propagates through assignments; it is cut by `.shape/.ndim/
.dtype/.size`, `len()`, and `is None` comparisons — those are static
facts about traced values, and branching on them is exactly how this
codebase selects kernel variants.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import List, Optional, Set, Tuple

from repro.analysis.common import Tree, Violation

CHECK = "tracing_safety"
SCAN_DIRS = ("src/repro/core", "src/repro/kernels")

# Attribute reads that yield static (python-int/dtype) facts: accessing
# them on a traced value produces an UNtraced value.
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
# Calls whose result is static regardless of argument taint.
STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type", "range"}
# Python casts that concretize a tracer — flagged when fed a traced value.
CAST_CALLS = {"float", "int", "bool"}


def _is_none_compare(test: ast.expr) -> bool:
    return isinstance(test, ast.Compare) and \
        all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)


def _jit_static_names(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """None if `fn` is not jit-decorated; else its static_argnames."""
    for dec in fn.decorator_list:
        if isinstance(dec, (ast.Name, ast.Attribute)):
            name = dec.id if isinstance(dec, ast.Name) else dec.attr
            if name == "jit":
                return set()
        if isinstance(dec, ast.Call):
            f = dec.func
            fname = f.id if isinstance(f, ast.Name) else \
                f.attr if isinstance(f, ast.Attribute) else ""
            if fname == "partial" and dec.args:
                a0 = dec.args[0]
                a0name = a0.id if isinstance(a0, ast.Name) else \
                    a0.attr if isinstance(a0, ast.Attribute) else ""
                if a0name == "jit":
                    static: Set[str] = set()
                    for kw in dec.keywords:
                        if kw.arg == "static_argnames":
                            for c in ast.walk(kw.value):
                                if isinstance(c, ast.Constant) and \
                                        isinstance(c.value, str):
                                    static.add(c.value)
                    return static
    return None


def _param_names(fn) -> List[str]:
    a = fn.args
    params = a.posonlyargs + a.args + a.kwonlyargs
    if a.vararg:
        params = params + [a.vararg]
    if a.kwarg:
        params = params + [a.kwarg]
    return [p.arg for p in params]


class _Taint:
    def __init__(self, seed: Set[str]) -> None:
        self.names = set(seed)

    def expr(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Compare) and _is_none_compare(node):
            return False
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in STATIC_CALLS:
                return False
            parts = [f] + list(node.args) + \
                [kw.value for kw in node.keywords]
            return any(self.expr(p) for p in parts)
        if isinstance(node, (ast.Lambda, ast.FunctionDef)):
            return False
        return any(self.expr(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    def _taint_target(self, target: ast.expr) -> bool:
        changed = False
        for n in ast.walk(target):
            if isinstance(n, ast.Name) and n.id not in self.names:
                self.names.add(n.id)
                changed = True
        return changed

    def propagate(self, fn) -> None:
        """Fixpoint pass: assignments from tainted expressions taint
        their targets; nested function/lambda parameters are tainted
        (scan/cond bodies and index maps receive traced operands)."""
        changed = True
        while changed:
            changed = False
            for n in ast.walk(fn):
                if isinstance(n, (ast.FunctionDef, ast.Lambda)) and n is not fn:
                    for p in _param_names(n):
                        if p not in self.names:
                            self.names.add(p)
                            changed = True
                elif isinstance(n, ast.Assign):
                    if self.expr(n.value):
                        for t in n.targets:
                            changed |= self._taint_target(t)
                elif isinstance(n, (ast.AnnAssign, ast.AugAssign,
                                    ast.NamedExpr)):
                    if n.value is not None and self.expr(n.value):
                        changed |= self._taint_target(n.target)
                elif isinstance(n, ast.For):
                    if self.expr(n.iter):
                        changed |= self._taint_target(n.target)


def _flag(fn, seed: Set[str], rel: str, where: str,
          violations: List[Violation]) -> None:
    taint = _Taint(seed)
    taint.propagate(fn)
    for n in ast.walk(fn):
        if isinstance(n, (ast.If, ast.While, ast.IfExp)):
            kind = {"If": "if", "While": "while",
                    "IfExp": "conditional expression"}[type(n).__name__]
            if not _is_none_compare(n.test) and taint.expr(n.test):
                violations.append(Violation(
                    CHECK, rel, n.lineno,
                    f"Python-level `{kind}` on a traced value in {where} "
                    f"(crashes or concretizes at trace time)"))
        elif isinstance(n, ast.Assert):
            if not _is_none_compare(n.test) and taint.expr(n.test):
                violations.append(Violation(
                    CHECK, rel, n.lineno,
                    f"`assert` on a traced value in {where} (trace-time "
                    f"TracerBoolConversionError)"))
        elif isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name) and f.id in CAST_CALLS and \
                    n.args and taint.expr(n.args[0]):
                violations.append(Violation(
                    CHECK, rel, n.lineno,
                    f"`{f.id}()` concretizes a traced value in {where}"))
            elif isinstance(f, ast.Attribute) and f.attr == "item" and \
                    taint.expr(f.value):
                violations.append(Violation(
                    CHECK, rel, n.lineno,
                    f"`.item()` concretizes a traced value in {where}"))


def run(tree: Tree) -> List[Violation]:
    violations: List[Violation] = []
    for rel in tree.iter_py(*SCAN_DIRS):
        mod = tree.parse(rel)
        if mod is None:
            continue
        in_kernels = "kernels" in PurePosixPath(rel).parts
        seen_kernel_bodies = set()
        if in_kernels:
            for fn in ast.walk(mod):
                if isinstance(fn, ast.FunctionDef):
                    refs = {p for p in _param_names(fn) if p.endswith("_ref")}
                    if refs:
                        seen_kernel_bodies.add(fn)
                        _flag(fn, refs, rel,
                              f"Pallas kernel body '{fn.name}'", violations)
        for fn in mod.body:
            if not isinstance(fn, ast.FunctionDef) or fn in seen_kernel_bodies:
                continue
            static = _jit_static_names(fn)
            if static is None:
                continue
            traced = {p for p in _param_names(fn)
                      if p not in static and p != "self"}
            _flag(fn, traced, rel, f"jit function '{fn.name}'", violations)
    return violations
