"""Check 2 — quant-registry exhaustiveness (DESIGN.md §15).

`types.QUANT_KINDS` and `quantize.quant_variants` are THE registry of
quantization families. Every kind must be wired through the KBest
dispatch (`_get_dist_fn` / `_get_expand_fn`), the save/load sidecar
arrays, a configs/kbest.py preset, and the benchmarks/ablation.py sweep
— and tests/benchmarks must not hand-enumerate quant lists (the drift
bug class: a new kind lands in the registry but not in the sweeps).

The per-kind sidecar tokens live in KIND_SIDECARS below: adding a kind
to QUANT_KINDS without registering its persisted-array names here fails
the lint, which is exactly the reminder that save()/load() need a case.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.common import (Tree, Violation, assigned_tuple_of_strings,
                                   class_def, keyword_arg, methods_of,
                                   missing_file, referenced_names,
                                   string_constants)

CHECK = "registry"
TYPES = "src/repro/core/types.py"
QUANTIZE = "src/repro/core/quantize.py"
INDEX = "src/repro/core/index.py"
PRESETS = "src/repro/configs/kbest.py"
ABLATION = "benchmarks/ablation.py"

# kind -> array keys save() must write and load() must read for it.
# "none" persists nothing beyond db/graph. A kind missing from this map
# is itself a violation (forces the sidecar story to be decided with the
# kind, not discovered at load time).
KIND_SIDECARS: Dict[str, Tuple[str, ...]] = {
    "none": (),
    "pq": ("pq_codebooks", "pq_codes", "ivf_codebooks"),
    "pq4": ("pq_codebooks", "pq_codes", "ivf_codebooks"),
    "sq": ("sq_scale", "sq_zero", "sq_codes"),
    "bin": ("bin_rot", "bin_codes", "ivf_bin_rot"),
}

# Hand-list detection: a single list/tuple/set literal whose direct
# elements include >= this many registry names is treated as a
# hand-maintained enumeration. 2-element pairs like ("graph", "pq4")
# parametrize cases legitimately; 3+ is a sweep that must derive from
# quant_variants instead.
HAND_LIST_MIN = 3


def _variants(mod: ast.Module) -> Tuple[Set[str], Set[str], Optional[int]]:
    """(variant_names, kinds_covered, lineno) from quant_variants()'s
    returned dict literal; kinds come from dict(kind="x") / {"kind": "x"}
    values."""
    for n in mod.body:
        if isinstance(n, ast.FunctionDef) and n.name == "quant_variants":
            names: Set[str] = set()
            kinds: Set[str] = set()
            for d in ast.walk(n):
                if not isinstance(d, ast.Dict):
                    continue
                for k, v in zip(d.keys, d.values):
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        if k.value == "kind" and isinstance(v, ast.Constant):
                            kinds.add(v.value)
                        else:
                            names.add(k.value)
            for call in ast.walk(n):
                if isinstance(call, ast.Call):
                    kw = keyword_arg(call, "kind")
                    if isinstance(kw, ast.Constant) and isinstance(kw.value, str):
                        kinds.add(kw.value)
            return names, kinds, n.lineno
    return set(), set(), None


def run(tree: Tree) -> List[Violation]:
    violations: List[Violation] = []

    types_mod = tree.parse(TYPES)
    if types_mod is None:
        return [missing_file(CHECK, TYPES, "QUANT_KINDS registry lives here")]
    kinds = assigned_tuple_of_strings(types_mod, "QUANT_KINDS")
    if kinds is None:
        return [Violation(CHECK, TYPES, 1,
                          "QUANT_KINDS tuple-of-strings not found")]

    # --- quant_variants covers every kind, and only registered kinds
    qz_mod = tree.parse(QUANTIZE)
    if qz_mod is None:
        violations.append(missing_file(CHECK, QUANTIZE,
                                       "quant_variants lives here"))
    else:
        names, vkinds, lineno = _variants(qz_mod)
        if lineno is None:
            violations.append(Violation(CHECK, QUANTIZE, 1,
                                        "quant_variants() not found"))
        else:
            for kind in kinds:
                if kind not in vkinds:
                    violations.append(Violation(
                        CHECK, QUANTIZE, lineno,
                        f"quant_variants() has no variant with "
                        f"kind='{kind}' (registry drift)"))
            for kind in sorted(vkinds - set(kinds)):
                violations.append(Violation(
                    CHECK, QUANTIZE, lineno,
                    f"quant_variants() uses kind='{kind}' which is not in "
                    f"types.QUANT_KINDS"))
        ivf_kinds = assigned_tuple_of_strings(qz_mod, "IVF_QUANT_KINDS")
        if ivf_kinds is None:
            violations.append(Violation(
                CHECK, QUANTIZE, 1,
                "IVF_QUANT_KINDS tuple not found (benchmarks derive their "
                "ivf-* rows from it)"))
        else:
            for kind in ivf_kinds:
                if kind not in kinds:
                    violations.append(Violation(
                        CHECK, QUANTIZE, 1,
                        f"IVF_QUANT_KINDS contains '{kind}' which is not "
                        f"in types.QUANT_KINDS"))

    # --- KBest dispatch handles every kind ("none" dispatches as "full")
    idx_mod = tree.parse(INDEX)
    if idx_mod is None:
        violations.append(missing_file(CHECK, INDEX,
                                       "KBest dispatch lives here"))
    else:
        kbest = class_def(idx_mod, "KBest")
        meths = methods_of(kbest) if kbest else {}
        for meth_name in ("_get_dist_fn", "_get_expand_fn"):
            meth = meths.get(meth_name)
            if meth is None:
                violations.append(Violation(
                    CHECK, INDEX, 1, f"KBest.{meth_name} not found"))
                continue
            strings = string_constants(meth)
            for kind in kinds:
                token = "full" if kind == "none" else kind
                if token not in strings:
                    violations.append(Violation(
                        CHECK, INDEX, meth.lineno,
                        f"KBest.{meth_name} does not handle kind "
                        f"'{kind}' (expected the '{token}' branch)"))
        # --- save/load persist every kind's sidecar arrays
        for meth_name in ("save", "load"):
            meth = meths.get(meth_name)
            if meth is None:
                violations.append(Violation(
                    CHECK, INDEX, 1, f"KBest.{meth_name} not found"))
                continue
            strings = string_constants(meth)
            for kind in kinds:
                if kind not in KIND_SIDECARS:
                    violations.append(Violation(
                        CHECK, INDEX, meth.lineno,
                        f"kind '{kind}' has no sidecar-array entry in "
                        f"analysis/registry.py KIND_SIDECARS — register "
                        f"its persisted arrays with the kind"))
                    continue
                for token in KIND_SIDECARS[kind]:
                    if token not in strings:
                        violations.append(Violation(
                            CHECK, INDEX, meth.lineno,
                            f"KBest.{meth_name} does not handle the "
                            f"'{token}' array of kind '{kind}'"))

    # --- configs/kbest.py constructs a preset for every non-none kind
    cfg_mod = tree.parse(PRESETS)
    if cfg_mod is None:
        violations.append(missing_file(CHECK, PRESETS,
                                       "per-kind presets live here"))
    else:
        preset_kinds: Set[str] = set()
        for call in ast.walk(cfg_mod):
            if isinstance(call, ast.Call):
                kw = keyword_arg(call, "kind")
                if isinstance(kw, ast.Constant) and isinstance(kw.value, str):
                    preset_kinds.add(kw.value)
        for kind in kinds:
            if kind != "none" and kind not in preset_kinds:
                violations.append(Violation(
                    CHECK, PRESETS, 1,
                    f"no preset constructs QuantConfig(kind='{kind}')"))

    # --- the ablation sweep derives from the registry
    abl_mod = tree.parse(ABLATION)
    if abl_mod is None:
        violations.append(missing_file(CHECK, ABLATION,
                                       "quant ablation lives here"))
    elif "quant_variants" not in referenced_names(abl_mod):
        violations.append(Violation(
            CHECK, ABLATION, 1,
            "quant ablation does not derive its sweep from "
            "quantize.quant_variants"))

    # --- no hand-enumerated quant lists in tests/ or benchmarks/
    match_names = set(kinds) | {"full", "pq8", "pq4+u8lut"} \
        | {"ivf-" + k for k in kinds}
    if qz_mod is not None:
        vnames, _, _ = _variants(qz_mod)
        match_names |= vnames | {"ivf-" + v for v in vnames}
    for rel in tree.iter_py("tests", "benchmarks"):
        mod = tree.parse(rel)
        if mod is None:
            continue
        for node in ast.walk(mod):
            if not isinstance(node, (ast.List, ast.Tuple, ast.Set)):
                continue
            hits = [e.value for e in node.elts
                    if isinstance(e, ast.Constant) and
                    isinstance(e.value, str) and e.value in match_names]
            if len(hits) >= HAND_LIST_MIN:
                violations.append(Violation(
                    CHECK, rel, node.lineno,
                    f"hand-enumerated quant list {hits} — derive it from "
                    f"quantize.quant_variants / IVF_QUANT_KINDS so new "
                    f"kinds cannot drift out of the sweep"))
    return violations
