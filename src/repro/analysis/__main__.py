"""CLI for kbest-lint: `python -m repro.analysis [--report] [--check NAME]
[--root PATH]`. Exits 0 iff the tree is violation-free."""
from __future__ import annotations

import argparse
import sys

from repro.analysis import CHECKS, default_root, run_all, run_check, vmem
from repro.analysis.common import Tree


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant checks for the KBest tree "
                    "(DESIGN.md §15)")
    ap.add_argument("--check", choices=sorted(CHECKS),
                    help="run a single check (default: all five)")
    ap.add_argument("--report", action="store_true",
                    help="also print the per-kernel VMEM residency table")
    ap.add_argument("--root", default=None,
                    help="tree to check (default: this checkout)")
    args = ap.parse_args(argv)

    root = args.root if args.root is not None else default_root()
    if args.report:
        print(vmem.report(Tree(root)))
        print()

    violations = (run_check(args.check, root) if args.check
                  else run_all(root))
    for v in violations:
        print(v)
    names = sorted({v.check for v in violations})
    print(f"kbest-lint: {len(violations)} violation(s)"
          + (f" [{', '.join(names)}]" if names else "")
          + f" in {root}")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
