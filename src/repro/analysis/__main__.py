"""CLI for kbest-lint: `python -m repro.analysis [--report] [--check NAME]
[--root PATH] [--json PATH]`. Exits 0 iff the tree is violation-free."""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.analysis import CHECKS, cost, default_root, run_all, run_check, \
    vmem
from repro.analysis.common import Tree


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant checks for the KBest tree "
                    "(DESIGN.md §15/§16)")
    ap.add_argument("--check", choices=sorted(CHECKS),
                    help="run a single check (default: all seven)")
    ap.add_argument("--report", action="store_true",
                    help="also print the per-kernel VMEM residency and "
                         "cost-model tables")
    ap.add_argument("--root", default=None,
                    help="tree to check (default: this checkout)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write findings + the vmem/cost tables as JSON "
                         "(the CI lint artifact)")
    args = ap.parse_args(argv)

    root = args.root if args.root is not None else default_root()
    if args.report:
        tree = Tree(root)
        if args.check in (None, vmem.CHECK):
            print(vmem.report(tree))
            print()
        if args.check in (None, cost.CHECK):
            print(cost.report(tree))
            print()

    violations = (run_check(args.check, root) if args.check
                  else run_all(root))
    for v in violations:
        print(v)
    names = sorted({v.check for v in violations})
    print(f"kbest-lint: {len(violations)} violation(s)"
          + (f" [{', '.join(names)}]" if names else "")
          + f" in {root}")

    if args.json:
        tree = Tree(root)
        payload = {
            "root": str(root),
            "ok": not violations,
            "violations": [dataclasses.asdict(v) for v in violations],
            "vmem": [dataclasses.asdict(e) for e in vmem.estimate(tree)],
            "cost": cost.cost_model(tree),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")

    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
