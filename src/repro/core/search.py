"""Batched best-first graph traversal (paper Algorithm 1 + Eq. 3).

Hardware adaptation (DESIGN.md §2): the paper runs one search per CPU
thread; a TPU has no independent scalar threads, so we run a *batch* of Q
queries in SIMD lockstep inside one `jax.lax.while_loop`, with a per-query
`active` mask. Each iteration expands one node per active query and computes
distances to its M neighbors in a single (Q, M, d) batched operation — the
paper's 1-to-B SIMD batching (H1) lifted to 2-D (Q-to-B) so it saturates the
MXU/VPU. Queries that satisfy the early-termination test (Eq. 3) or exhaust
their queue are masked off and become idle lanes (measured as
`lockstep_overhead` in the benchmarks).

The per-step neighbor batch B: the paper sizes B to the L1 cache (Eq. 1).
On TPU the analogous constraint is VMEM tile sizing, which lives inside the
Pallas kernels (repro/kernels); at this level B = M always, because XLA
pipelines the whole (Q, M, d) gather+reduce.

Visited-set semantics: "bitmap" mode implements Algorithm 1 exactly (a
packed per-query bitmap of distance-computed nodes, O(n/8) bytes/query);
"queue" mode (default) dedupes only against the candidate queue, which may
recompute distances of long-evicted nodes but never changes recall — the
classic memory/compute trade for huge n.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import queue as qmod
from repro.core.types import SearchConfig

# dist_fn(queries (Q, d), nbr_ids (Q, M)) -> (Q, M) float32 distances.
DistFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


class SearchStats(NamedTuple):
    n_hops: jnp.ndarray        # (Q,) i32 nodes expanded
    n_dist: jnp.ndarray        # (Q,) i32 distances computed
    early_terminated: jnp.ndarray  # (Q,) bool
    iters: jnp.ndarray         # () i32 lockstep iterations of the batch


class _Carry(NamedTuple):
    dists: jnp.ndarray    # (Q, L)
    ids: jnp.ndarray      # (Q, L)
    visited: jnp.ndarray  # (Q, L)
    bitmap: jnp.ndarray   # (Q, W) u32 (W=1 dummy in queue mode)
    et_ctr: jnp.ndarray   # (Q,) i32
    et_fired: jnp.ndarray  # (Q,) bool
    active: jnp.ndarray   # (Q,) bool
    hops: jnp.ndarray     # (Q,) i32
    ndist: jnp.ndarray    # (Q,) i32
    it: jnp.ndarray       # () i32


def _dedupe_row(ids: jnp.ndarray) -> jnp.ndarray:
    """Mask (to -1) ids duplicating an earlier position in the row."""
    m = ids.shape[0]
    dup = jnp.any(
        (ids[:, None] == ids[None, :]) & (jnp.arange(m)[None, :] < jnp.arange(m)[:, None]),
        axis=1,
    )
    return jnp.where(dup | (ids < 0), -1, ids)


def _bitmap_test(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(W,) u32 bitmap, (M,) ids -> (M,) bool seen (invalid ids -> False)."""
    safe = jnp.maximum(ids, 0)
    words = bitmap[safe >> 5]
    bit = (words >> (safe.astype(jnp.uint32) & 31)) & 1
    return (bit == 1) & (ids >= 0)


def _bitmap_set_raw(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Set bits via scatter-add. PRECONDITION: ids are deduped within the
    batch AND none of their bits are already set — the add only equals a
    bitwise-or while the added bits are disjoint; a duplicate id (or an
    already-set bit) carries into the adjacent bit and corrupts the visited
    set. The traversal loop satisfies this by construction (_dedupe_row +
    _bitmap_test masking); every other caller must use _bitmap_set."""
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    word_idx = jnp.where(valid, safe >> 5, bitmap.shape[0] - 1)
    val = jnp.where(valid, jnp.uint32(1) << (safe.astype(jnp.uint32) & 31), jnp.uint32(0))
    return bitmap.at[word_idx].add(val)


def _bitmap_set(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Set bits for valid ids: safe for ANY input — dedupes within the
    batch and skips already-set bits before the scatter-add, so colliding
    entry seeds (e.g. strided seeds wrapping onto the medoid) cannot carry
    into adjacent bits."""
    ids = _dedupe_row(ids)
    ids = jnp.where(_bitmap_test(bitmap, ids), -1, ids)
    return _bitmap_set_raw(bitmap, ids)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n_total", "dist_fn"),
)
def search(
    graph: jnp.ndarray,            # (n, M) i32, -1 padded
    queries: jnp.ndarray,          # (Q, d) f32
    entry_ids: jnp.ndarray,        # (E,) i32 entry points
    *,
    dist_fn: DistFn,
    cfg: SearchConfig,
    n_total: int,
    valid_mask: Optional[jnp.ndarray] = None,  # (Q,) bool; None => all valid
) -> Tuple[jnp.ndarray, jnp.ndarray, SearchStats]:
    """Batched ANN search. Returns (dists (Q, k), ids (Q, k), stats).

    `valid_mask` marks real queries in a shape-padded batch: invalid lanes
    start inactive, so they are the same free lockstep-idle lanes as
    early-terminated queries and add no distance computations. Their rows
    still hold the (garbage) seed entries — callers mask outputs (the
    serving engine's `search_padded` does).
    """
    Q = queries.shape[0]
    L, k, M = cfg.L, cfg.k, graph.shape[1]
    t_pos = jnp.int32(int(cfg.et_t_frac * L))
    W = (n_total + 31) // 32 if cfg.visited_mode == "bitmap" else 1

    # ---- init: seed the queue with the entry points -----------------------
    e_ids = jnp.broadcast_to(entry_ids[None, :], (Q, entry_ids.shape[0]))
    e_dists = dist_fn(queries, e_ids)
    q0 = qmod.init_queue(L)
    q0 = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (Q,) + x.shape), q0)

    def _seed(qq, nd, ni):
        out, _, _ = qmod.merge_insert(qq, nd, ni)
        return out

    queue = jax.vmap(_seed)(qmod.Queue(q0[0], q0[1], q0[2]), e_dists, e_ids)
    bitmap = jnp.zeros((Q, W), dtype=jnp.uint32)
    if cfg.visited_mode == "bitmap":
        bitmap = jax.vmap(_bitmap_set)(bitmap, e_ids)

    # seed distances count: the init dist_fn call above already computed one
    # distance per valid entry seed — starting ndist at 0 undercounted every
    # family's n_dist by n_entries (benchmarks, EngineStats dists/query).
    # Gated on the lane being valid: padded lanes must keep the documented
    # "invalid lanes add no distance computations" invariant.
    active0 = (jnp.ones((Q,), bool) if valid_mask is None
               else valid_mask.astype(bool))
    n_seed = jnp.where(active0,
                       jnp.sum(e_ids >= 0, axis=1), 0).astype(jnp.int32)

    carry = _Carry(
        dists=queue.dists, ids=queue.ids, visited=queue.visited,
        bitmap=bitmap,
        et_ctr=jnp.zeros((Q,), jnp.int32),
        et_fired=jnp.zeros((Q,), bool),
        active=active0,
        hops=jnp.zeros((Q,), jnp.int32),
        ndist=n_seed,
        it=jnp.int32(0),
    )

    def cond(c: _Carry):
        return jnp.any(c.active) & (c.it < cfg.hops_bound)

    def body(c: _Carry) -> _Carry:
        queue = qmod.Queue(c.dists, c.ids, c.visited)
        idx, has = jax.vmap(qmod.pick_unvisited)(queue)
        expand = c.active & has
        v = jnp.where(expand, queue.ids[jnp.arange(Q), idx], -1)
        queue = jax.vmap(qmod.mark_visited)(queue, idx, expand)

        # gather neighbor lists; -1 rows for inactive lanes
        nbrs = jnp.where(v[:, None] >= 0, graph[jnp.maximum(v, 0)], -1)
        nbrs = jax.vmap(_dedupe_row)(nbrs)

        bitmap = c.bitmap
        if cfg.visited_mode == "bitmap":
            seen = jax.vmap(_bitmap_test)(bitmap, nbrs)
            nbrs = jnp.where(seen, -1, nbrs)
            # nbrs are deduped (above) and seen-masked: raw scatter is safe
            bitmap = jax.vmap(_bitmap_set_raw)(bitmap, nbrs)

        # --- the 1-to-B (here Q-to-B) batched distance computation (H1) ---
        nd = dist_fn(queries, nbrs)
        nd = jnp.where(nbrs >= 0, nd, jnp.inf)
        n_new = jnp.sum(nbrs >= 0, axis=1).astype(jnp.int32)

        merged, best_rank, _ = jax.vmap(qmod.merge_insert)(queue, nd, nbrs)
        queue = jax.tree.map(
            lambda new, old: jnp.where(
                expand.reshape((Q,) + (1,) * (new.ndim - 1)), new, old),
            merged, queue)

        # --- early termination, Eq. 3 ---
        beyond = best_rank > t_pos
        et_ctr = jnp.where(expand, jnp.where(beyond, c.et_ctr + 1, 0), c.et_ctr)
        fired = c.et_fired | (cfg.early_term & expand & (et_ctr >= cfg.et_patience))

        hops = c.hops + expand.astype(jnp.int32)
        ndist = c.ndist + jnp.where(expand, n_new, 0)
        active = c.active & has & ~fired & (hops < cfg.hops_bound)
        return _Carry(queue.dists, queue.ids, queue.visited, bitmap,
                      et_ctr, fired, active, hops, ndist, c.it + 1)

    out = jax.lax.while_loop(cond, body, carry)
    final = qmod.Queue(out.dists, out.ids, out.visited)
    dists_k, ids_k = jax.vmap(lambda q: qmod.topk(q, k))(final)
    stats = SearchStats(out.hops, out.ndist, out.et_fired, out.it)
    return dists_k, ids_k, stats


def make_dist_fn(db: jnp.ndarray, metric: str, impl: str = "ref") -> DistFn:
    """Gather-then-distance backend over a database (n, d).

    impl="ref" is the jnp oracle; impl="kernel" routes through the Pallas
    gather_dist kernel (interpret-mode on CPU).
    """
    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(queries, nbr_ids):
            return kops.gather_dist(queries, db, nbr_ids, metric=metric)
        return fn

    from repro.core.distance import batched_one_to_many

    def fn(queries, nbr_ids):
        vecs = db[jnp.maximum(nbr_ids, 0)]
        return batched_one_to_many(queries, vecs, metric)
    return fn
