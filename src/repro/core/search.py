"""Batched beam-parallel best-first traversal (paper Algorithm 1 + Eq. 3).

Hardware adaptation (DESIGN.md §2): the paper runs one search per CPU
thread; a TPU has no independent scalar threads, so we run a *batch* of Q
queries in SIMD lockstep inside one `jax.lax.while_loop`, with a per-query
`active` mask. Each iteration expands the top-W unvisited candidates per
active query (`SearchConfig.beam_width`, the beam) and computes distances to
all W·M gathered neighbors in a single (Q, W·M) batched operation — the
paper's 1-to-B SIMD batching (H1) lifted to 2-D (Q-to-B) so it saturates the
MXU/VPU, with the beam multiplying B so the gather pipeline always has W·M
rows in flight to hide latency behind (H2). A wider beam cuts the lockstep
trip count ~W× and amortizes per-iteration queue maintenance; W=1 is the
classic best-first traversal and stays bit-identical to it. Queries that
satisfy the early-termination test (Eq. 3) or exhaust their queue are masked
off and become idle lanes (measured as `lockstep_overhead` in the
benchmarks).

Queue maintenance per iteration is a sort of the W·M-entry candidate block
(small) plus a stable merge of two sorted runs (`queue.merge_insert_beam`) —
never a full argsort of the (L + W·M) concatenation. Early termination under
a beam is defined per expansion in beam order: expansion w's best surviving
candidate gets its insertion rank in the merged order, and Eq. 3's patience
counter consumes the W ranks sequentially (w = 0 first), exactly as if the
expansions had happened one per iteration — so W=1 semantics are the
original ones by construction, and a beam can only reach the patience
threshold at the same expansion count or earlier.

The per-step distance batch: the paper sizes B to the L1 cache (Eq. 1). On
TPU the analogous constraint is VMEM tile sizing inside the Pallas kernels
(repro/kernels); `SearchConfig.batch_B` optionally chunks the W·M candidate
axis into batch_B-sized distance calls (0 = one fused call). The
full-precision kernel path under a beam routes through `fused_expand`
(kernels/traverse_step.py): gather + distance + in-kernel sort emitting the
sorted candidate block directly.

Visited-set semantics: "bitmap" mode implements Algorithm 1 exactly (a
packed per-query bitmap of distance-computed nodes, O(n/8) bytes/query);
"queue" mode (default) dedupes only against the candidate queue, which may
recompute distances of long-evicted nodes but never changes recall — the
classic memory/compute trade for huge n.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import queue as qmod
from repro.core.types import SearchConfig

# dist_fn(queries (Q, d), nbr_ids (Q, B)) -> (Q, B) float32 distances.
DistFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

# expand_fn(queries (Q, d), nbr_ids (Q, W*M)) ->
#   (sorted_dists (Q, T), sorted_ids (Q, T), per-beam bests (Q, W),
#    earlier-expansion tie counts (Q, W))
# — the fused gather+distance+sort step (kernels/traverse_step.py). T is
# min(L, W*M): only the L best new candidates can survive the merge.
ExpandFn = Callable[[jnp.ndarray, jnp.ndarray],
                    Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                          jnp.ndarray]]


class SearchStats(NamedTuple):
    n_hops: jnp.ndarray        # (Q,) i32 nodes expanded
    n_dist: jnp.ndarray        # (Q,) i32 distances computed
    early_terminated: jnp.ndarray  # (Q,) bool
    iters: jnp.ndarray         # () i32 lockstep iterations of the batch


class _Carry(NamedTuple):
    dists: jnp.ndarray    # (Q, L)
    ids: jnp.ndarray      # (Q, L)
    visited: jnp.ndarray  # (Q, L)
    bitmap: jnp.ndarray   # (Q, nwords) u32 (nwords=1 dummy in queue mode)
    et_ctr: jnp.ndarray   # (Q,) i32
    et_fired: jnp.ndarray  # (Q,) bool
    active: jnp.ndarray   # (Q,) bool
    hops: jnp.ndarray     # (Q,) i32
    ndist: jnp.ndarray    # (Q,) i32
    it: jnp.ndarray       # () i32


def _dedupe_row(ids: jnp.ndarray) -> jnp.ndarray:
    """Mask (to -1) ids duplicating an earlier position in the row (the
    shared lower-triangle helper, kept under its historical name for the
    traversal's callers/tests)."""
    return qmod.dedupe_ids(ids)


def _bitmap_test(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(nwords,) u32 bitmap, (M,) ids -> (M,) bool seen (invalid -> False)."""
    safe = jnp.maximum(ids, 0)
    words = bitmap[safe >> 5]
    bit = (words >> (safe.astype(jnp.uint32) & 31)) & 1
    return (bit == 1) & (ids >= 0)


def _bitmap_set_raw(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Set bits via scatter-add. PRECONDITION: ids are deduped within the
    batch AND none of their bits are already set — the add only equals a
    bitwise-or while the added bits are disjoint; a duplicate id (or an
    already-set bit) carries into the adjacent bit and corrupts the visited
    set. The traversal loop satisfies this by construction (dedupe_ids +
    _bitmap_test masking); every other caller must use _bitmap_set."""
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    word_idx = jnp.where(valid, safe >> 5, bitmap.shape[0] - 1)
    val = jnp.where(valid, jnp.uint32(1) << (safe.astype(jnp.uint32) & 31), jnp.uint32(0))
    return bitmap.at[word_idx].add(val)


def _bitmap_set(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Set bits for valid ids: safe for ANY input — dedupes within the
    batch and skips already-set bits before the scatter-add, so colliding
    entry seeds (e.g. strided seeds wrapping onto the medoid) cannot carry
    into adjacent bits."""
    ids = qmod.dedupe_ids(ids)
    ids = jnp.where(_bitmap_test(bitmap, ids), -1, ids)
    return _bitmap_set_raw(bitmap, ids)


def _dist_chunked(dist_fn: DistFn, queries: jnp.ndarray, ids: jnp.ndarray,
                  batch_B: int) -> jnp.ndarray:
    """Distance computation over the candidate axis, optionally chunked into
    batch_B-sized calls (SearchConfig.batch_B; 0 = one fused (Q, W·M) call).
    Chunking never changes the candidate set or ordering (pinned by tests);
    distance bits may drift a few ulp across chunk shapes, as any
    hardware retiling would."""
    C = ids.shape[1]
    if batch_B <= 0 or batch_B >= C:
        return dist_fn(queries, ids)
    return jnp.concatenate(
        [dist_fn(queries, ids[:, s:s + batch_B])
         for s in range(0, C, batch_B)], axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n_total", "dist_fn", "expand_fn"),
)
def search(
    graph: jnp.ndarray,            # (n, M) i32, -1 padded
    queries: jnp.ndarray,          # (Q, d) f32
    entry_ids: jnp.ndarray,        # (E,) i32 entry points
    *,
    dist_fn: DistFn,
    cfg: SearchConfig,
    n_total: int,
    valid_mask: Optional[jnp.ndarray] = None,  # (Q,) bool; None => all valid
    expand_fn: Optional[ExpandFn] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, SearchStats]:
    """Batched ANN search. Returns (dists (Q, k), ids (Q, k), stats).

    `valid_mask` marks real queries in a shape-padded batch: invalid lanes
    start inactive, so they are the same free lockstep-idle lanes as
    early-terminated queries and add no distance computations. Their rows
    still hold the (garbage) seed entries — callers mask outputs (the
    serving engine's `search_padded` does).

    `expand_fn`, when given, replaces dist_fn + host-side block sort with
    the fused gather+distance+sort kernel for the per-iteration candidate
    block (the seed distances still go through dist_fn). It is only engaged
    when `cfg.batch_B == 0` — a chunked distance path is a dist_fn property,
    so batch_B falls back to dist_fn and the knob is always honored.
    """
    Q = queries.shape[0]
    L, k, M = cfg.L, cfg.k, graph.shape[1]
    W = cfg.beam_width
    t_pos = jnp.int32(int(cfg.et_t_frac * L))
    nwords = (n_total + 31) // 32 if cfg.visited_mode == "bitmap" else 1
    use_fused = expand_fn is not None and cfg.batch_B == 0

    # ---- init: seed the queue with the entry points -----------------------
    e_ids = jnp.broadcast_to(entry_ids[None, :], (Q, entry_ids.shape[0]))
    e_dists = dist_fn(queries, e_ids)
    q0 = qmod.init_queue(L)
    q0 = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (Q,) + x.shape), q0)

    def _seed(qq, nd, ni):
        out, _, _ = qmod.merge_insert(qq, nd, ni)
        return out

    queue = jax.vmap(_seed)(qmod.Queue(q0[0], q0[1], q0[2]), e_dists, e_ids)
    bitmap = jnp.zeros((Q, nwords), dtype=jnp.uint32)
    if cfg.visited_mode == "bitmap":
        bitmap = jax.vmap(_bitmap_set)(bitmap, e_ids)

    # seed distances count: the init dist_fn call above already computed one
    # distance per valid entry seed — starting ndist at 0 undercounted every
    # family's n_dist by n_entries (benchmarks, EngineStats dists/query).
    # Gated on the lane being valid: padded lanes must keep the documented
    # "invalid lanes add no distance computations" invariant.
    active0 = (jnp.ones((Q,), bool) if valid_mask is None
               else valid_mask.astype(bool))
    n_seed = jnp.where(active0,
                       jnp.sum(e_ids >= 0, axis=1), 0).astype(jnp.int32)

    carry = _Carry(
        dists=queue.dists, ids=queue.ids, visited=queue.visited,
        bitmap=bitmap,
        et_ctr=jnp.zeros((Q,), jnp.int32),
        et_fired=jnp.zeros((Q,), bool),
        active=active0,
        hops=jnp.zeros((Q,), jnp.int32),
        ndist=n_seed,
        it=jnp.int32(0),
    )

    def cond(c: _Carry):
        return jnp.any(c.active) & (c.it < cfg.hops_bound)

    def body(c: _Carry) -> _Carry:
        queue = qmod.Queue(c.dists, c.ids, c.visited)
        # the W closest unvisited candidates per lane, by the sorted
        # invariant (first W unvisited slots in queue order)
        idxs, has = jax.vmap(lambda qq: qmod.pick_top_w(qq, W))(queue)
        exp_w = c.active[:, None] & has                    # (Q, W)
        any_has = jnp.any(has, axis=1)
        exp_any = c.active & any_has
        v = jnp.where(exp_w, queue.ids[jnp.arange(Q)[:, None], idxs], -1)
        queue = jax.vmap(qmod.mark_visited_many)(queue, idxs, exp_w)

        # gather all W neighbor lists at once; -1 rows for idle slots/lanes.
        # The flat (W*M,) row is deduped against EARLIER flat positions, so
        # overlap between beam expansions collapses exactly like duplicates
        # within one adjacency row (beam order = flat order).
        nbrs = jnp.where(v[..., None] >= 0, graph[jnp.maximum(v, 0)], -1)
        flat = jax.vmap(qmod.dedupe_ids)(nbrs.reshape(Q, W * M))

        bitmap = c.bitmap
        if cfg.visited_mode == "bitmap":
            seen = jax.vmap(_bitmap_test)(bitmap, flat)
            flat = jnp.where(seen, -1, flat)
            # flat ids are deduped (above) and seen-masked: raw scatter safe
            bitmap = jax.vmap(_bitmap_set_raw)(bitmap, flat)

        n_new = jnp.sum(flat >= 0, axis=1).astype(jnp.int32)

        # candidates already in the queue are masked BEFORE the distance
        # computation (merge_insert would discard them anyway, so results
        # are unchanged; the fused kernel path requires it, and n_new above
        # keeps the historical "computed distances" accounting)
        flat = jnp.where(jax.vmap(qmod.in_queue_mask)(queue, flat), -1, flat)

        # --- the 1-to-B (here Q-to-W·B) batched distance step (H1 + H2) ---
        if use_fused:
            sd, si, bests, ties = expand_fn(queries, flat)
            merged = jax.vmap(qmod.merge_sorted_runs)(queue, sd, si)
            ranks = jax.vmap(qmod.block_ranks)(queue, sd, bests, ties)
        else:
            nd = _dist_chunked(dist_fn, queries, flat, cfg.batch_B)
            nd = jnp.where(flat >= 0, nd, jnp.inf)
            # flat is fully deduped/in-queue-masked above, so the merge can
            # skip _dedupe_new's O((WM)² + WM·L) re-derivation per step
            merged, ranks = jax.vmap(
                lambda qq, d, i: qmod.merge_expand(qq, d, i, W))(
                    queue, nd, flat)
        queue = jax.tree.map(
            lambda new, old: jnp.where(
                exp_any.reshape((Q,) + (1,) * (new.ndim - 1)), new, old),
            merged, queue)

        # --- early termination, Eq. 3: per expansion, in beam order ---
        beyond = ranks > t_pos                             # (Q, W)
        et_ctr, fired = c.et_ctr, c.et_fired
        for w in range(W):
            ex = exp_w[:, w]
            et_ctr = jnp.where(ex, jnp.where(beyond[:, w], et_ctr + 1, 0),
                               et_ctr)
            fired = fired | (cfg.early_term & ex
                             & (et_ctr >= cfg.et_patience))

        hops = c.hops + jnp.sum(exp_w, axis=1).astype(jnp.int32)
        ndist = c.ndist + jnp.where(exp_any, n_new, 0)
        active = c.active & any_has & ~fired & (hops < cfg.hops_bound)
        return _Carry(queue.dists, queue.ids, queue.visited, bitmap,
                      et_ctr, fired, active, hops, ndist, c.it + 1)

    out = jax.lax.while_loop(cond, body, carry)
    final = qmod.Queue(out.dists, out.ids, out.visited)
    dists_k, ids_k = jax.vmap(lambda q: qmod.topk(q, k))(final)
    stats = SearchStats(out.hops, out.ndist, out.et_fired, out.it)
    return dists_k, ids_k, stats


def make_dist_fn(db: jnp.ndarray, metric: str, impl: str = "ref") -> DistFn:
    """Gather-then-distance backend over a database (n, d).

    impl="ref" is the jnp oracle; impl="kernel" routes through the Pallas
    gather_dist kernel (interpret-mode on CPU).
    """
    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(queries, nbr_ids):
            return kops.gather_dist(queries, db, nbr_ids, metric=metric)
        return fn

    from repro.core.distance import batched_one_to_many

    def fn(queries, nbr_ids):
        vecs = db[jnp.maximum(nbr_ids, 0)]
        return batched_one_to_many(queries, vecs, metric)
    return fn


def make_expand_fn(db: jnp.ndarray, metric: str, *, L: int,
                   n_beam: int) -> ExpandFn:
    """Fused gather+distance+sort backend for the beam traversal's per-
    iteration candidate block (full-precision rows): one Pallas kernel
    gathers the W·M neighbor rows with scalar-prefetch double-buffered DMA
    (H2), computes distances on-chip (H1), and emits the per-query sorted
    top-min(L, W·M) block plus per-expansion bests (the Eq. 3 operand) —
    see kernels/traverse_step.py."""
    from repro.kernels import ops as kops

    def fn(queries, nbr_ids):
        return kops.fused_expand(queries, db, nbr_ids, metric=metric,
                                 L=L, n_beam=n_beam)
    return fn
