"""Index parameter tuning against held-out queries with exact ground truth.

Early termination (paper §3.2, A3): the paper determines (t, tau_max) with
a two-stage dry-run — initialize t at ~60% of L, binary-search tau_max
under the recall constraint, then sweep t down from 60% toward 30% of L
keeping the fastest setting that still meets the recall target.
`tune_early_term` reproduces that procedure.

Quantization (A4, DESIGN.md §13/§14): `tune_quant_kind` sweeps every
registered quantization family (quantize.quant_variants — the SAME
registry benchmarks/ablation.py enumerates, asserted in tests to cover
types.QUANT_KINDS) over one shared graph build and picks the
smallest-code-bytes family that still meets the recall target.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.types import SearchConfig
from repro.data.vectors import recall_at_k


def _eval(index, queries, gt_ids, scfg: SearchConfig) -> Tuple[float, float]:
    d, i, stats = index.search(queries, search_cfg=scfg, with_stats=True)
    rec = recall_at_k(np.asarray(i), gt_ids, scfg.k)
    hops = float(np.asarray(stats.n_hops).mean())
    return rec, hops


def tune_early_term(index, queries: np.ndarray, gt_ids: np.ndarray,
                    base_cfg: SearchConfig, recall_target: float = 0.95,
                    patience_hi: int = 64) -> SearchConfig:
    """Two-stage (t, tau_max) search as in the paper. Returns a tuned cfg."""
    best = dataclasses.replace(base_cfg, early_term=False)
    rec0, hops0 = _eval(index, queries, gt_ids, best)
    # An ET config is admissible if recall does not drop below
    # min(recall_target, no-ET recall) - small slack.
    floor = min(recall_target, rec0) - 0.005
    best_hops = hops0

    for t_frac in (0.6, 0.5, 0.4, 0.3):
        # binary search the smallest admissible patience for this t
        lo, hi = 1, patience_hi
        admissible = None
        while lo <= hi:
            mid = (lo + hi) // 2
            cand = dataclasses.replace(base_cfg, early_term=True,
                                       et_t_frac=t_frac, et_patience=mid)
            rec, hops = _eval(index, queries, gt_ids, cand)
            if rec >= floor:
                admissible = (cand, hops)
                hi = mid - 1      # try more aggressive (smaller patience)
            else:
                lo = mid + 1
        if admissible and admissible[1] < best_hops:
            best, best_hops = admissible
    return best


def tune_quant_kind(index, queries: np.ndarray, gt_ids: np.ndarray,
                    recall_target: float = 0.90, pq_m: int = 16):
    """Sweep every registered quantization family over `index`'s existing
    graph (one build, quantizer retrained per variant — the quant_ablation
    clone trick) and return (best_name, rows).

    rows: [{"quant", "recall", "code_bytes"}] for every variant in
    quantize.quant_variants(pq_m). best_name is the variant with the
    SMALLEST code bytes/vector whose recall meets recall_target (ties keep
    the higher recall); falls back to the highest-recall variant when none
    meets the target."""
    from repro.core import quantize as qz
    from repro.core.index import KBest
    from repro.core.types import QuantConfig

    assert index.graph is not None, "tune_quant_kind needs a graph index"
    rows = []
    for name, qkw in qz.quant_variants(pq_m=pq_m).items():
        cfg = dataclasses.replace(index.config,
                                  quant=QuantConfig(kmeans_iters=6, **qkw))
        idx = KBest(cfg)
        idx.db, idx.graph, idx.entry, idx.order = (index.db, index.graph,
                                                   index.entry, index.order)
        idx._train_quant(idx.db)
        _, ids = idx.search(queries)
        rows.append({"quant": name,
                     "recall": recall_at_k(np.asarray(ids), gt_ids,
                                           cfg.search.k),
                     "code_bytes": qz.code_bytes_per_vector(idx)})
    ok = [r for r in rows if r["recall"] >= recall_target]
    if ok:
        best = min(ok, key=lambda r: (r["code_bytes"], -r["recall"]))
    else:
        best = max(rows, key=lambda r: r["recall"])
    return best["quant"], rows
