"""Index parameter tuning against held-out queries with exact ground truth.

Early termination (paper §3.2, A3): the paper determines (t, tau_max) with
a two-stage dry-run — initialize t at ~60% of L, binary-search tau_max
under the recall constraint, then sweep t down from 60% toward 30% of L
keeping the fastest setting that still meets the recall target.
`tune_early_term` reproduces that procedure.

Quantization (A4, DESIGN.md §13/§14): `tune_quant_kind` sweeps every
registered quantization family (quantize.quant_variants — the SAME
registry benchmarks/ablation.py enumerates, asserted in tests to cover
types.QUANT_KINDS) over one shared graph build and picks the
smallest-code-bytes family that still meets the recall target.

Full-knob tuning (DESIGN.md §16): `tune_config` generalizes both to the
whole search-knob grid (quant kind x L x nprobe/beam x rescore_factor),
using the static cost model (repro.analysis.cost) to order candidates
by predicted cost and measuring cheapest-first until the recall SLO is
met — everything costlier is pruned without ever being measured.

All measurement goes through `_eval`, memoized per index on the frozen
SearchConfig key (`_memo_eval`): the ET binary search, the grid stage
and the ET stage share one cache, so no config is ever measured twice.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import SearchConfig
from repro.data.vectors import recall_at_k


def _eval(index, queries, gt_ids, scfg: SearchConfig) -> Tuple[float, float]:
    d, i, stats = index.search(queries, search_cfg=scfg, with_stats=True)
    rec = recall_at_k(np.asarray(i), gt_ids, scfg.k)
    hops = float(np.asarray(stats.n_hops).mean())
    return rec, hops


def _memo_eval(index, queries, gt_ids
               ) -> Callable[[SearchConfig], Tuple[float, float]]:
    """Memoize `_eval` on the (hashable, frozen) SearchConfig: duplicate
    configs across binary-search probes / grid stages hit the cache
    instead of re-searching. The cache dict is exposed as `.cache` so
    tests can pin the call-count reduction."""
    cache: Dict[SearchConfig, Tuple[float, float]] = {}

    def ev(scfg: SearchConfig) -> Tuple[float, float]:
        if scfg not in cache:
            cache[scfg] = _eval(index, queries, gt_ids, scfg)
        return cache[scfg]

    ev.cache = cache
    return ev


def tune_early_term(index, queries: np.ndarray, gt_ids: np.ndarray,
                    base_cfg: SearchConfig, recall_target: float = 0.95,
                    patience_hi: int = 64, _ev=None) -> SearchConfig:
    """Two-stage (t, tau_max) search as in the paper. Returns a tuned cfg.

    `_ev` lets tune_config share its memoized evaluator so the ET stage
    never re-measures a config the grid stage already priced."""
    ev = _ev if _ev is not None else _memo_eval(index, queries, gt_ids)
    best = dataclasses.replace(base_cfg, early_term=False)
    rec0, hops0 = ev(best)
    # An ET config is admissible if recall does not drop below
    # min(recall_target, no-ET recall) - small slack.
    floor = min(recall_target, rec0) - 0.005
    best_hops = hops0

    for t_frac in (0.6, 0.5, 0.4, 0.3):
        # binary search the smallest admissible patience for this t
        lo, hi = 1, patience_hi
        admissible = None
        while lo <= hi:
            mid = (lo + hi) // 2
            cand = dataclasses.replace(base_cfg, early_term=True,
                                       et_t_frac=t_frac, et_patience=mid)
            rec, hops = ev(cand)
            if rec >= floor:
                admissible = (cand, hops)
                hi = mid - 1      # try more aggressive (smaller patience)
            else:
                lo = mid + 1
        if admissible and admissible[1] < best_hops:
            best, best_hops = admissible
    return best


def tune_quant_kind(index, queries: np.ndarray, gt_ids: np.ndarray,
                    recall_target: float = 0.90, pq_m: int = 16):
    """Sweep every registered quantization family over `index`'s existing
    graph (one build, quantizer retrained per variant — the quant_ablation
    clone trick) and return (best_name, rows).

    rows: [{"quant", "recall", "code_bytes"}] for every variant in
    quantize.quant_variants(pq_m). best_name is the variant with the
    SMALLEST code bytes/vector whose recall meets recall_target (ties keep
    the higher recall); falls back to the highest-recall variant when none
    meets the target."""
    from repro.core import quantize as qz
    from repro.core.index import KBest
    from repro.core.types import QuantConfig

    assert index.graph is not None, "tune_quant_kind needs a graph index"
    rows = []
    for name, qkw in qz.quant_variants(pq_m=pq_m).items():
        cfg = dataclasses.replace(index.config,
                                  quant=QuantConfig(kmeans_iters=6, **qkw))
        idx = KBest(cfg)
        idx.db, idx.graph, idx.entry, idx.order = (index.db, index.graph,
                                                   index.entry, index.order)
        idx._train_quant(idx.db)
        _, ids = idx.search(queries)
        rows.append({"quant": name,
                     "recall": recall_at_k(np.asarray(ids), gt_ids,
                                           cfg.search.k),
                     "code_bytes": qz.code_bytes_per_vector(idx)})
    ok = [r for r in rows if r["recall"] >= recall_target]
    if ok:
        best = min(ok, key=lambda r: (r["code_bytes"], -r["recall"]))
    else:
        best = max(rows, key=lambda r: r["recall"])
    return best["quant"], rows


# ------------------------------------------------- full-knob model-guided tuner

@dataclasses.dataclass
class TuneResult:
    """tune_config's emitted preset + the pruning/measurement audit trail
    (DESIGN.md §16)."""

    config: object                # IndexConfig with the tuned SearchConfig
    rows: List[dict]              # measured candidates, cheapest-first
    grid_size: int                # enumerated (kind x knob) combinations
    n_deduped: int                # collapsed as analytically equivalent
    n_measured: int
    n_pruned: int                 # grid_size - n_measured (never searched)
    recall_tune: float            # winner recall on the tuning split
    recall_holdout: float         # winner recall on the held-out split
    recall_slo: float
    notes: List[str]


def _default_pq_m(d: int) -> int:
    for m in (32, 16, 8, 4, 2):
        if d % m == 0:
            return m
    return 1


def tune_config(x: np.ndarray, queries: np.ndarray, gt_ids: np.ndarray, *,
                metric: str = "l2", index_type: str = "ivf", k: int = 10,
                recall_slo: float = 0.90, slo_margin: float = 0.02,
                pq_m: int = 0, grid: Optional[dict] = None, build=None,
                et_stage: bool = True, max_measure: int = 0,
                dist_impl: str = "ref", kmeans_iters: int = 6) -> TuneResult:
    """Offline full-knob tuner (DESIGN.md §16): recall SLO + sample
    workload in, ready IndexConfig out.

    Pipeline: enumerate quant-kind registry x configs/kbest.tune_grid
    knobs, collapse analytically-equivalent candidates (identical
    widened queue + rescore depth => identical search), price the rest
    with the static cost model (repro.analysis.cost), then measure
    cheapest-first until a config clears recall_slo + slo_margin on the
    tuning split (the margin buys headroom for the tune->holdout
    generalization gap — the first config to scrape PAST the SLO on a
    finite sample tends to land under it on fresh queries) —
    every costlier candidate is pruned WITHOUT being measured, and the
    max_measure budget (default grid/8, always <= grid/2) bounds the
    frontier, so at least half the grid is pruned analytically. Builds
    are shared per quant kind (one IVF build per kind; one graph build
    total, quantizers retrained per kind — the tune_quant_kind clone
    trick). Graph winners then run the paper's §3.2 ET stage through
    the same memoized evaluator. Recall is validated on a held-out
    query split the tuner never measured against.
    """
    from repro.analysis import cost as cost_mod
    from repro.configs import kbest as kcfg
    from repro.core import quantize as qz
    from repro.core.index import KBest
    from repro.core.types import (QUANT_KINDS, BuildConfig, IVFConfig,
                                  IndexConfig, QuantConfig)

    x = np.asarray(x)
    n, d = x.shape
    notes: List[str] = []
    pq_m = pq_m or _default_pq_m(d)

    # tune/holdout split of the sample workload
    n_tune = max(1, len(queries) // 2)
    tune_q, hold_q = queries[:n_tune], queries[n_tune:]
    tune_gt, hold_gt = gt_ids[:n_tune], gt_ids[n_tune:]
    if len(hold_q) == 0:
        hold_q, hold_gt = tune_q, tune_gt
        notes.append("single-query sample: holdout == tune split")

    kinds = qz.IVF_QUANT_KINDS if index_type == "ivf" else QUANT_KINDS
    knobs = grid if grid is not None else kcfg.tune_grid(index_type)
    build = build or BuildConfig(M=16, knn_k=24, refine_iters=1,
                                 refine_cands=48)

    def quant_for(kind: str) -> QuantConfig:
        if kind in ("pq", "pq4"):
            return QuantConfig(kind=kind, pq_m=pq_m,
                               kmeans_iters=kmeans_iters)
        return QuantConfig(kind=kind)

    # ---- enumerate the full grid ------------------------------------
    cands: List[dict] = []
    grid_size = 0
    second = knobs.get("nprobe" if index_type == "ivf" else "beam_width",
                       (1,))
    for kind in kinds:
        if kind == "pq4" and pq_m % 2:
            notes.append(f"pq4 skipped: pq_m={pq_m} is odd for d={d}")
            continue
        rfs = knobs.get("rescore_factor", (8,)) if kind == "bin" else (8,)
        for L in knobs.get("L", (64,)):
            if L < k:
                continue
            for snd in second:
                for rf in rfs:
                    grid_size += 1
                    skw = dict(L=L, k=k, dist_impl=dist_impl,
                               rescore_factor=rf)
                    if index_type == "ivf":
                        skw["nprobe"] = snd
                    else:
                        skw["beam_width"] = min(snd, L)
                    scfg = SearchConfig(**skw)
                    cfg = IndexConfig(
                        dim=d, metric=metric, index_type=index_type,
                        build=build, quant=quant_for(kind), search=scfg,
                        ivf=IVFConfig(nlist=0, kmeans_iters=kmeans_iters))
                    cands.append({"kind": kind, "cfg": cfg, "scfg": scfg})

    # ---- analytic stage: dedupe equivalents, price the rest ---------
    seen = set()
    priced: List[dict] = []
    for c in cands:
        w = cost_mod.workload_from(c["cfg"], n=n, Q=len(tune_q))
        if index_type == "ivf":
            key = (c["kind"], w.nprobe, cost_mod.wide_L(w),
                   cost_mod.ivf_rerank_depth(w))
        else:
            key = (c["kind"], w.W, cost_mod.wide_L(w),
                   cost_mod.graph_rerank_depth(w))
        if key in seen:
            continue
        seen.add(key)
        c["pred_s"] = cost_mod.search_cost(w).seconds
        priced.append(c)
    n_deduped = grid_size - len(priced)
    priced.sort(key=lambda c: c["pred_s"])

    if max_measure <= 0:
        max_measure = max(4, grid_size // 8)
    max_measure = min(max_measure, max(1, grid_size // 2))

    # ---- measurement stage: cheapest-first until the SLO is met -----
    builds: Dict[str, object] = {}
    evs: Dict[str, object] = {}
    base_graph = None

    def index_for(c) -> object:
        nonlocal base_graph
        kind = c["kind"]
        if kind not in builds:
            if index_type == "ivf":
                builds[kind] = KBest(c["cfg"]).add(x)
            else:
                if base_graph is None:
                    base_cfg = dataclasses.replace(c["cfg"],
                                                   quant=QuantConfig())
                    base_graph = KBest(base_cfg).add(x)
                if kind == "none":
                    builds[kind] = base_graph
                else:
                    idx = KBest(c["cfg"])
                    idx.db, idx.graph, idx.entry, idx.order = (
                        base_graph.db, base_graph.graph, base_graph.entry,
                        base_graph.order)
                    idx._train_quant(idx.db)
                    builds[kind] = idx
            evs[kind] = _memo_eval(builds[kind], tune_q, tune_gt)
        return builds[kind]

    rows: List[dict] = []
    winner = None
    for c in priced[:max_measure]:
        index_for(c)
        rec, hops = evs[c["kind"]](c["scfg"])
        rows.append({"kind": c["kind"], "L": c["scfg"].L,
                     "nprobe": c["scfg"].nprobe,
                     "beam_width": c["scfg"].beam_width,
                     "rescore_factor": c["scfg"].rescore_factor,
                     "pred_us": c["pred_s"] * 1e6 / max(len(tune_q), 1),
                     "recall": rec, "hops": hops})
        if rec >= recall_slo + slo_margin:
            winner = c
            break
    if winner is None:
        if not rows:
            raise ValueError("empty candidate grid")
        best_i = max(range(len(rows)), key=lambda i: rows[i]["recall"])
        winner = priced[best_i]
        if rows[best_i]["recall"] >= recall_slo:
            notes.append(f"no measured candidate cleared the SLO with "
                         f"slo_margin={slo_margin}; emitting the best "
                         f"measured (recall={rows[best_i]['recall']:.3f} "
                         f">= {recall_slo} without margin)")
        else:
            notes.append(f"no measured candidate met the {recall_slo} SLO "
                         f"within the max_measure={max_measure} budget; "
                         f"emitting the best measured (recall="
                         f"{rows[best_i]['recall']:.3f})")

    # ---- ET stage (graph only) + holdout validation -----------------
    idx = index_for(winner)
    tuned_scfg = winner["scfg"]
    if et_stage and index_type == "graph":
        tuned_scfg = tune_early_term(idx, tune_q, tune_gt, tuned_scfg,
                                     recall_target=recall_slo,
                                     _ev=evs[winner["kind"]])
    recall_tune = evs[winner["kind"]](tuned_scfg)[0]
    recall_holdout = _eval(idx, hold_q, hold_gt, tuned_scfg)[0]

    return TuneResult(
        config=dataclasses.replace(winner["cfg"], search=tuned_scfg),
        rows=rows, grid_size=grid_size, n_deduped=n_deduped,
        n_measured=len(rows), n_pruned=grid_size - len(rows),
        recall_tune=recall_tune, recall_holdout=recall_holdout,
        recall_slo=recall_slo, notes=notes)
