"""Early-termination parameter tuning (paper §3.2, A3).

The paper determines (t, tau_max) with a two-stage dry-run: initialize t at
~60% of L, binary-search tau_max under the recall constraint, then sweep t
down from 60% toward 30% of L keeping the fastest setting that still meets
the recall target. This module reproduces that procedure against a held-out
query sample with exact ground truth.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.types import SearchConfig
from repro.data.vectors import recall_at_k


def _eval(index, queries, gt_ids, scfg: SearchConfig) -> Tuple[float, float]:
    d, i, stats = index.search(queries, search_cfg=scfg, with_stats=True)
    rec = recall_at_k(np.asarray(i), gt_ids, scfg.k)
    hops = float(np.asarray(stats.n_hops).mean())
    return rec, hops


def tune_early_term(index, queries: np.ndarray, gt_ids: np.ndarray,
                    base_cfg: SearchConfig, recall_target: float = 0.95,
                    patience_hi: int = 64) -> SearchConfig:
    """Two-stage (t, tau_max) search as in the paper. Returns a tuned cfg."""
    best = dataclasses.replace(base_cfg, early_term=False)
    rec0, hops0 = _eval(index, queries, gt_ids, best)
    # An ET config is admissible if recall does not drop below
    # min(recall_target, no-ET recall) - small slack.
    floor = min(recall_target, rec0) - 0.005
    best_hops = hops0

    for t_frac in (0.6, 0.5, 0.4, 0.3):
        # binary search the smallest admissible patience for this t
        lo, hi = 1, patience_hi
        admissible = None
        while lo <= hi:
            mid = (lo + hi) // 2
            cand = dataclasses.replace(base_cfg, early_term=True,
                                       et_t_frac=t_frac, et_patience=mid)
            rec, hops = _eval(index, queries, gt_ids, cand)
            if rec >= floor:
                admissible = (cand, hops)
                hi = mid - 1      # try more aggressive (smaller patience)
            else:
                lo = mid + 1
        if admissible and admissible[1] < best_hops:
            best, best_hops = admissible
    return best
