"""Graph reordering for memory locality (paper §3.2, Algorithm 2).

Host-side build-time transform. The paper's algorithm:
  1. build an MST of the proximity graph (edge weight = vector distance),
  2. root it at the entry node,
  3. compute subtree sizes met(v) with an iterative DFS,
  4. emit nodes by a priority traversal that always pops the frontier node
     with the largest subtree — clustering dense regions contiguously while
     *preserving* the long-range shortcuts that Cuthill-McKee style BFS
     relabelling destroys.

On TPU the payoff is DMA locality: consecutive beam frontiers hit nearby
HBM rows, so the gather_dist kernel touches fewer distinct pages per step
(measured as `locality` in benchmarks/ablation.py).

Also provides Cuthill-McKee as the baseline the paper compares against, and
`apply_order` to physically permute vectors + graph.

Used by: `core/index.py: KBest.add` (graph family) as the step after
`core/refine.py`'s edge refinement, selected by `BuildConfig.reorder`
("mst" | "cm" | "none"); the tuned presets in `configs/kbest.py` all pick
"mst". The search path never sees the permutation — `KBest._search_impl`
translates result ids back through the stored order, and `save/load`
round-trips it. Ablated as `locality` in `benchmarks/ablation.py`.
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np


class _DSU:
    def __init__(self, n: int):
        self.p = np.arange(n)

    def find(self, x: int) -> int:
        p = self.p
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.p[ra] = rb
        return True


def _mst_children(graph: np.ndarray, weights: np.ndarray, root: int
                  ) -> Tuple[list, np.ndarray]:
    """Kruskal MST over the (directed, padded) index graph, undirected view.

    Returns (children adjacency list rooted at `root`, parent array). Nodes
    disconnected from the root's component are attached under the root so
    the ordering is always a full permutation.
    """
    n, M = graph.shape
    us = np.repeat(np.arange(n), M)
    vs = graph.reshape(-1)
    ws = weights.reshape(-1)
    valid = vs >= 0
    us, vs, ws = us[valid], vs[valid], ws[valid]
    order = np.argsort(ws, kind="stable")

    dsu = _DSU(n)
    adj = [[] for _ in range(n)]
    for e in order:
        u, v = int(us[e]), int(vs[e])
        if dsu.union(u, v):
            adj[u].append(v)
            adj[v].append(u)

    # root the forest at `root`; BFS assigns parents
    parent = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    children = [[] for _ in range(n)]
    stack = [root]
    seen[root] = True
    while stack:
        u = stack.pop()
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                parent[v] = u
                children[u].append(v)
                stack.append(v)
    # attach stray components under the root
    for v in np.nonzero(~seen)[0]:
        if v != root:
            parent[v] = root
            children[root].append(int(v))
            seen[v] = True
    return children, parent


def mst_reorder(graph: np.ndarray, weights: np.ndarray, entry: int) -> np.ndarray:
    """Algorithm 2. Returns `order`: order[i] = old id stored at new slot i."""
    n = graph.shape[0]
    children, _ = _mst_children(graph, weights, entry)

    # --- lines 5-16: subtree sizes via iterative DFS (post-order) ---------
    met = np.ones(n, dtype=np.int64)
    stack = [(entry, False)]
    while stack:
        u, processed = stack.pop()
        if processed:
            for v in children[u]:
                met[u] += met[v]
        else:
            stack.append((u, True))
            for v in reversed(children[u]):
                stack.append((v, False))

    # --- lines 17-23: priority traversal by descending subtree size -------
    # Interpretation note (DESIGN.md §10): with one GLOBAL heap, similarly
    # sized subtrees interleave and locality is lost; the paper's stated
    # goal ("frequently co-accessed nodes — those in large subtrees — are
    # stored contiguously") is realized by a largest-subtree-first DFS:
    # after emitting u, u's own children are prioritized before returning
    # to u's siblings. This keeps every subtree contiguous while visiting
    # larger subtrees first — we implement that reading (measurably better
    # mean edge gap; both variants exposed for the ablation).
    order = np.empty(n, dtype=np.int64)
    stack = [entry]
    pos = 0
    while stack:
        u = stack.pop()
        order[pos] = u
        pos += 1
        # push children in ASCENDING met so the largest is popped first
        for v in sorted(children[u], key=lambda c: met[c]):
            stack.append(v)
    assert pos == n
    return order


def mst_reorder_global_heap(graph: np.ndarray, weights: np.ndarray,
                            entry: int) -> np.ndarray:
    """Literal global-priority-queue reading of Algorithm 2 lines 17-23
    (kept for the ablation comparison)."""
    n = graph.shape[0]
    children, _ = _mst_children(graph, weights, entry)
    met = np.ones(n, dtype=np.int64)
    stack = [(entry, False)]
    while stack:
        u, processed = stack.pop()
        if processed:
            for v in children[u]:
                met[u] += met[v]
        else:
            stack.append((u, True))
            for v in reversed(children[u]):
                stack.append((v, False))
    order = np.empty(n, dtype=np.int64)
    heap = [(-met[entry], entry)]
    pos = 0
    while heap:
        _, u = heapq.heappop(heap)
        order[pos] = u
        pos += 1
        for v in children[u]:
            heapq.heappush(heap, (-met[v], v))
    assert pos == n
    return order


def cuthill_mckee(graph: np.ndarray, entry: int) -> np.ndarray:
    """Baseline: BFS relabelling, neighbors visited in ascending degree."""
    n, _ = graph.shape
    deg = (graph >= 0).sum(axis=1)
    seen = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    from collections import deque
    dq = deque([entry])
    seen[entry] = True
    while pos < n:
        if not dq:  # next unvisited component, lowest degree first
            rest = np.nonzero(~seen)[0]
            nxt = rest[np.argmin(deg[rest])]
            dq.append(int(nxt))
            seen[nxt] = True
        u = dq.popleft()
        order[pos] = u
        pos += 1
        nbrs = graph[u]
        nbrs = nbrs[nbrs >= 0]
        nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
        for v in nbrs:
            if not seen[v]:
                seen[v] = True
                dq.append(int(v))
    return order


def apply_order(order: np.ndarray, db: np.ndarray, graph: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Physically permute (db, graph) by `order`.

    Returns (db', graph', new_of_old) where new_of_old maps old->new ids
    (needed to translate the entry point and any external id references).
    """
    n = order.shape[0]
    new_of_old = np.empty(n, dtype=np.int64)
    new_of_old[order] = np.arange(n)
    db2 = np.asarray(db)[order]
    g = np.asarray(graph)[order]
    g2 = np.where(g >= 0, new_of_old[np.maximum(g, 0)], -1).astype(np.int32)
    return db2, g2, new_of_old


def bandwidth_stats(graph: np.ndarray) -> dict:
    """Locality metrics of a layout: mean/max |pi(u) - pi(v)| over edges."""
    n, _ = graph.shape
    us = np.repeat(np.arange(n), graph.shape[1])
    vs = graph.reshape(-1)
    valid = vs >= 0
    gaps = np.abs(us[valid] - vs[valid])
    return {
        "mean_gap": float(gaps.mean()),
        "p95_gap": float(np.percentile(gaps, 95)),
        "max_gap": int(gaps.max()),
    }
