"""Distributed vector search over a device mesh (DESIGN.md §2, last row).

The paper's KBest is single-node; at pod scale the standard architecture
(the one Milvus deploys KBest into) is shard-per-device + merge:

  * the database rows AND the per-shard proximity graph are sharded over
    every mesh axis (a flat "shards" view of the mesh);
  * each device runs the full KBest traversal on its local shard;
  * per-shard top-k results are all-gathered and reduced to a global top-k.

Graphs are built per shard (local ids), so no cross-device edges exist:
search is embarrassingly parallel until the final O(P·k) merge. Recall of a
sharded index is >= the single-shard index at equal per-shard L because each
shard runs its own full traversal (more total distance evaluations); the
QPS/recall trade is measured in benchmarks/scaling.py.

Implementation is `jax.shard_map` so the same code path lowers for the
(16, 16) single-pod and (2, 16, 16) multi-pod production meshes in the
dry-run, and runs on the 1-device CPU mesh in tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import search as search_mod
from repro.core.types import SearchConfig


def mesh_size(mesh: Mesh) -> int:
    out = 1
    for a in mesh.axis_names:
        out *= mesh.shape[a]
    return out


def build_sharded_search(mesh: Mesh, cfg: SearchConfig, metric: str,
                         n_local: int):
    """Returns a jit'd fn(db, graph, entries, queries) -> (dists, ids).

    db:      (P*n_local, d) row-sharded over the flattened mesh
    graph:   (P*n_local, M) sharded likewise, *local* ids in [0, n_local)
    entries: (P,) i32 per-shard entry points (local ids)
    queries: (Q, d) replicated
    Output:  (Q, k) replicated global top-k; ids are GLOBAL row ids.
    """
    axes = tuple(mesh.axis_names)
    row_spec = P(axes)           # dim0 sharded over every axis, flattened
    rep = P()
    p_tot = mesh_size(mesh)

    def local_search(db_l, graph_l, entry_l, queries):
        dist_fn = search_mod.make_dist_fn(db_l, metric, cfg.dist_impl)
        dists, ids, _ = search_mod.search(
            graph_l, queries, entry_l, dist_fn=dist_fn, cfg=cfg,
            n_total=n_local)
        # translate local -> global ids using this device's linear index
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        gids = jnp.where(ids >= 0, ids + idx * n_local, -1)
        # gather every shard's candidates and reduce to a global top-k
        all_d = jax.lax.all_gather(dists, axes)   # (P, Q, k)
        all_i = jax.lax.all_gather(gids, axes)
        Q, k = dists.shape
        all_d = all_d.reshape(p_tot, Q, k).transpose(1, 0, 2).reshape(Q, p_tot * k)
        all_i = all_i.reshape(p_tot, Q, k).transpose(1, 0, 2).reshape(Q, p_tot * k)
        neg, pos = jax.lax.top_k(-all_d, k)
        return -neg, jnp.take_along_axis(all_i, pos, axis=1)

    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec, rep),
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(fn)


def make_sharded_arrays(mesh: Mesh, db, graph, entries, queries):
    """device_put with the canonical shardings used by build_sharded_search."""
    axes = tuple(mesh.axis_names)
    row = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    return (jax.device_put(db, row), jax.device_put(graph, row),
            jax.device_put(entries, row), jax.device_put(queries, rep))
