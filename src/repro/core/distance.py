"""Reference distance functions (pure jnp).

These are the semantic oracles; the Pallas kernels in repro.kernels must
match them bit-for-tolerance. All distances are "smaller is more similar":
  l2     : squared Euclidean ||q - x||^2
  ip     : negative inner product  -<q, x>
  cosine : negative cosine similarity; callers normalize x at add() time so
           this reduces to ip on unit vectors.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import METRICS


def normalize(x: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def pairwise(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Full (nq, nx) distance matrix. q: (nq, d), x: (nx, d)."""
    assert metric in METRICS
    if metric == "l2":
        # ||q||^2 + ||x||^2 - 2 q.x — one GEMM + rank-1 corrections; this is
        # the Q-to-B decomposition the batch_dist kernel implements on MXU.
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xx = jnp.sum(x * x, axis=-1)[None, :]
        qx = q @ x.T
        return jnp.maximum(qq + xx - 2.0 * qx, 0.0)
    # ip / cosine (pre-normalized)
    return -(q @ x.T)


def one_to_many(q: jnp.ndarray, xs: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Distances from one query (d,) to a batch (B, d) — the paper's 1-to-B."""
    assert metric in METRICS
    if metric == "l2":
        diff = xs - q[None, :]
        return jnp.sum(diff * diff, axis=-1)
    return -(xs @ q)


def batched_one_to_many(q: jnp.ndarray, xs: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(Q, d) queries vs per-query neighbor batches (Q, B, d) -> (Q, B)."""
    assert metric in METRICS
    if metric == "l2":
        diff = xs - q[:, None, :]
        return jnp.sum(diff * diff, axis=-1)
    return -jnp.einsum("qbd,qd->qb", xs, q)
