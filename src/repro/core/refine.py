"""Index refinement (paper §3.2, A1): edge selection + 2-hop iteration.

Pipeline: kNN graph -> edge-selection rule -> F rounds of {expand candidates
to 2-hop neighborhood, re-select}. Three selection rules, all expressed as
one greedy sweep with a rule-specific acceptance predicate:

  alpha (Vamana/NSG): accept c iff for every already-selected s,
        d(node, c) < alpha * d(s, c)            (hnsw == alpha with a=1.0)
  ssg:  accept c iff for every selected s, the angle at `node` between
        (c - node) and (s - node) is >= theta.

The greedy sweep is vectorized over all nodes simultaneously (node-lanes);
per candidate step it needs d(c, s) for the <=M selected vectors, i.e. an
(n, M, d) batched distance — again the paper's Q-to-B workload. All heavy
steps are chunked over nodes to bound the gather footprint.

Used by: `core/index.py: KBest.add` (graph family) — `refine_graph` is the
pipeline stage between `core/build.py`'s kNN construction and
`core/reorder.py`'s relabeling, driven by the `BuildConfig` knobs
(select_rule / alpha / ssg_angle_deg / refine_iters / refine_cands /
search_passes); the per-dataset values live in `configs/kbest.py`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import _merge_topk


@functools.partial(jax.jit, static_argnames=("M", "rule", "metric"))
def select_edges(db: jnp.ndarray, rows: jnp.ndarray, cand_ids: jnp.ndarray,
                 cand_dists: jnp.ndarray, *, M: int, rule: str, metric: str,
                 alpha: float = 1.2, cos_theta: float = 0.5) -> jnp.ndarray:
    """Greedy rule-based pruning of sorted candidates to <=M edges per node.

    rows: (nc,) node ids this chunk refines; cand_ids/cand_dists: (nc, C)
    sorted ascending by distance, -1/inf padded. Returns (nc, M) int32.
    """
    nc, C = cand_ids.shape
    node_vecs = db[rows]                            # (nc, d)

    def step(j, state):
        sel_ids, sel_cnt, sel_vecs = state          # (nc, M), (nc,), (nc, M, d)
        cid = cand_ids[:, j]
        cdist = cand_dists[:, j]
        cvec = db[jnp.maximum(cid, 0)]              # (nc, d)

        slot_mask = jnp.arange(M)[None, :] < sel_cnt[:, None]
        if rule == "ssg":
            u = cvec - node_vecs
            v = sel_vecs - node_vecs[:, None, :]
            num = jnp.einsum("nmd,nd->nm", v, u)
            den = jnp.linalg.norm(v, axis=-1) * jnp.linalg.norm(u, axis=-1)[:, None]
            cos = num / jnp.maximum(den, 1e-12)
            violate = jnp.any(slot_mask & (cos > cos_theta), axis=1)
        else:  # alpha / hnsw; diversity geometry in L2 of the raw vectors
            diff = sel_vecs - cvec[:, None, :]
            d_sc = jnp.sum(diff * diff, axis=-1)
            d_pc = jnp.sum((cvec - node_vecs) ** 2, axis=-1)
            violate = jnp.any(
                slot_mask & (d_pc[:, None] >= (alpha * alpha) * d_sc), axis=1)

        accept = (cid >= 0) & jnp.isfinite(cdist) & ~violate & (sel_cnt < M)
        pos = jnp.minimum(sel_cnt, M - 1)
        hit = accept[:, None] & (jnp.arange(M)[None, :] == pos[:, None])
        sel_ids = jnp.where(hit, cid[:, None], sel_ids)
        sel_vecs = jnp.where(hit[:, :, None], cvec[:, None, :], sel_vecs)
        sel_cnt = sel_cnt + accept.astype(jnp.int32)
        return sel_ids, sel_cnt, sel_vecs

    init = (jnp.full((nc, M), -1, jnp.int32), jnp.zeros((nc,), jnp.int32),
            jnp.zeros((nc, M, db.shape[1]), db.dtype))
    sel_ids, sel_cnt, _ = jax.lax.fori_loop(0, C, step, init)
    # guarantee out-degree >= 1 (keep the closest candidate)
    empty = sel_cnt == 0
    sel_ids = sel_ids.at[:, 0].set(jnp.where(empty, cand_ids[:, 0], sel_ids[:, 0]))
    return sel_ids


def _chunk_dists(db: jnp.ndarray, rows: jnp.ndarray, ids: jnp.ndarray,
                 metric: str) -> jnp.ndarray:
    """d(db[rows[i]], db[ids[i, j]]) with -1 masked to inf. (nc, C)."""
    vecs = db[jnp.maximum(ids, 0)]
    base = db[rows]
    if metric == "l2":
        diff = vecs - base[:, None, :]
        out = jnp.sum(diff * diff, axis=-1)
    else:
        out = -jnp.einsum("ncd,nd->nc", vecs, base)
    return jnp.where(ids >= 0, out, jnp.inf)


@functools.partial(jax.jit, static_argnames=("C", "metric"))
def expand_two_hop(db: jnp.ndarray, graph: jnp.ndarray, rows: jnp.ndarray,
                   *, C: int, metric: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidates = 1-hop ∪ 2-hop neighbors of `rows`, deduped, top-C."""
    g1 = graph[rows]                                            # (nc, M)
    n, M = graph.shape
    g2 = jnp.where(g1[:, :, None] >= 0,
                   graph[jnp.maximum(g1, 0)], -1).reshape(g1.shape[0], M * M)
    cands = jnp.concatenate([g1, g2], axis=1)
    cands = jnp.where(cands == rows[:, None], -1, cands)
    dists = _chunk_dists(db, rows, cands, metric)
    nc = cands.shape[0]
    ids, dists = _merge_topk(cands, dists, jnp.full((nc, 1), -1, jnp.int32),
                             jnp.full((nc, 1), jnp.inf, jnp.float32), C)
    return ids, dists


def _reverse_proposals(graph: np.ndarray, cap: int) -> np.ndarray:
    """(n, cap) int32 of reverse-edge proposers per node (-1 padded)."""
    n, M = graph.shape
    out = np.full((n, cap), -1, dtype=np.int32)
    cnt = np.zeros(n, dtype=np.int64)
    us = np.repeat(np.arange(n, dtype=np.int32), M)
    vs = graph.reshape(-1)
    ok = vs >= 0
    for u, v in zip(us[ok], vs[ok]):
        c = cnt[v]
        if c < cap:
            out[v, c] = u
            cnt[v] = c + 1
    return out


def reverse_merge_select(db: jnp.ndarray, graph: np.ndarray, *, M: int,
                         rule: str, metric: str, alpha: float,
                         cos_theta: float, node_chunk: int = 512,
                         rev_cap: int = None) -> np.ndarray:
    """Vamana-style reverse-edge pass WITH re-pruning.

    Every edge u->v proposes v->u; instead of dropping proposals when v is
    full (which starves hub nodes and leaves the graph fragmented), each
    node re-runs the edge-selection rule over {current edges} ∪ {proposals}.
    The diversity rule then trades near-duplicate intra-cluster edges for
    long-range connectivity — this is what stitches cluster islands into one
    searchable component.
    """
    n = graph.shape[0]
    rev_cap = rev_cap or 2 * M
    rev = _reverse_proposals(np.asarray(graph), rev_cap)
    g = jnp.asarray(graph)
    rv = jnp.asarray(rev)
    rows_all = jnp.arange(n, dtype=jnp.int32)
    outs = []
    for s in range(0, n, node_chunk):
        e = min(s + node_chunk, n)
        rows = rows_all[s:e]
        pool = jnp.concatenate([g[s:e], rv[s:e]], axis=1)
        pool = jnp.where(pool == rows[:, None], -1, pool)
        d = _chunk_dists(db, rows, pool, metric)
        ci, cd = _merge_topk(pool, d, jnp.full((e - s, 1), -1, jnp.int32),
                             jnp.full((e - s, 1), jnp.inf, jnp.float32),
                             pool.shape[1])
        outs.append(select_edges(db, rows, ci, cd, M=M, rule=rule,
                                 metric=metric, alpha=alpha,
                                 cos_theta=cos_theta))
    return np.asarray(jnp.concatenate(outs, axis=0))


def add_reverse_edges(graph: np.ndarray, max_degree: int) -> np.ndarray:
    """Fill -1 slots with reverse edges (host-side build step).

    Standard Vamana/NSG post-pass: every edge u->v proposes v->u; accepted
    while v has spare capacity. Keeps the graph closer to strongly-connected.
    """
    graph = np.asarray(graph).copy()
    n, M = graph.shape
    assert max_degree <= M
    deg = (graph >= 0).sum(axis=1)
    existing = [set(row[row >= 0].tolist()) for row in graph]
    for u in range(n):
        for v in graph[u]:
            if v < 0:
                continue
            v = int(v)
            if deg[v] < max_degree and u not in existing[v]:
                graph[v, deg[v]] = u
                existing[v].add(u)
                deg[v] += 1
    return graph


def search_candidates(db: jnp.ndarray, graph: jnp.ndarray, rows: jnp.ndarray,
                      entry: int, metric: str, search_L: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Search-based candidate generation (paper: "refine each vertex's
    neighborhood based on search results from the initial kNN graph").

    Runs the batched KBest traversal with db[rows] as queries over the
    current graph from the global entry point, returning the final candidate
    queue per node. This is what creates cross-cluster backbone edges
    (NSG/Vamana-style) that pure kNN + 2-hop expansion cannot: remote nodes
    acquire edges toward the entry's basin, and the reverse-edge pass then
    makes the link bidirectional.
    """
    from repro.core import search as search_mod
    from repro.core.types import SearchConfig

    cfg = SearchConfig(L=search_L, k=search_L, early_term=False,
                       visited_mode="queue", n_entries=1)
    dist_fn = search_mod.make_dist_fn(db, metric, "ref")
    dists, ids, _ = search_mod.search(
        graph, db[rows], jnp.array([entry], jnp.int32), dist_fn=dist_fn,
        cfg=cfg, n_total=db.shape[0])
    ids = jnp.where(ids == rows[:, None], -1, ids)   # drop self
    dists = jnp.where(ids >= 0, dists, jnp.inf)
    return ids.astype(jnp.int32), dists


def refine_graph(db: jnp.ndarray, knn_ids: jnp.ndarray, knn_dists: jnp.ndarray,
                 *, M: int, rule: str, metric: str, alpha: float,
                 ssg_angle_deg: float, iters: int, cand_cap: int,
                 entry: int = 0, search_L: int = 48, search_passes: int = 1,
                 node_chunk: int = 512) -> np.ndarray:
    """Full A1 pipeline. Returns the final (n, M) int32 padded CSR graph."""
    cos_theta = float(np.cos(np.deg2rad(ssg_angle_deg)))
    n = db.shape[0]
    all_rows = jnp.arange(n, dtype=jnp.int32)

    def _select(cids, cdists):
        outs = []
        for s in range(0, n, node_chunk):
            e = min(s + node_chunk, n)
            outs.append(select_edges(
                db, all_rows[s:e], cids[s:e], cdists[s:e], M=M, rule=rule,
                metric=metric, alpha=alpha, cos_theta=cos_theta))
        return jnp.concatenate(outs, axis=0)

    if rule == "none":
        graph = knn_ids[:, :M]
    else:
        graph = _select(knn_ids, knn_dists)

    # --- phase 2 of A1: search-based neighborhood refinement ----------------
    # The graph searched during refinement is {current graph} ∪ {R random
    # long edges per node}. Vamana gets global percolation by *initializing*
    # with a random R-regular graph; augmenting the search graph with random
    # edges gives the same property (build-time searches can cross cluster
    # islands, so far-but-useful candidates enter the pools) without
    # polluting the final edge set.
    sel_rule = rule if rule != "none" else "alpha"
    rng = np.random.default_rng(0)
    n_rand = max(4, M // 4)
    for _ in range(0 if rule == "none" else search_passes):
        rand_edges = jnp.asarray(
            rng.integers(0, n, size=(n, n_rand), dtype=np.int32))
        search_graph = jnp.concatenate([jnp.asarray(graph), rand_edges], axis=1)
        cid_chunks, cd_chunks = [], []
        for s in range(0, n, node_chunk):
            e = min(s + node_chunk, n)
            sc_ids, sc_d = search_candidates(
                db, search_graph, all_rows[s:e], entry, metric, search_L)
            # pool: search results ∪ current edges ∪ original kNN
            pool_ids = jnp.concatenate(
                [sc_ids, graph[s:e], knn_ids[s:e]], axis=1)
            pool_d = jnp.concatenate(
                [sc_d, _chunk_dists(db, all_rows[s:e], graph[s:e], metric),
                 knn_dists[s:e]], axis=1)
            ci, cd = _merge_topk(
                pool_ids, pool_d,
                jnp.full((e - s, 1), -1, jnp.int32),
                jnp.full((e - s, 1), jnp.inf, jnp.float32), cand_cap)
            cid_chunks.append(ci)
            cd_chunks.append(cd)
        graph = _select(jnp.concatenate(cid_chunks, 0),
                        jnp.concatenate(cd_chunks, 0))
        # Vamana-style reverse pass with re-pruning: stitches islands.
        graph = jnp.asarray(reverse_merge_select(
            db, np.asarray(graph), M=M, rule=sel_rule, metric=metric,
            alpha=alpha, cos_theta=cos_theta, node_chunk=node_chunk))

    # --- phase 3 of A1: iterative 2-hop expansion ---------------------------
    for _ in range(iters):
        cid_chunks, cd_chunks = [], []
        for s in range(0, n, node_chunk):
            e = min(s + node_chunk, n)
            ci, cd = expand_two_hop(db, graph, all_rows[s:e], C=cand_cap,
                                    metric=metric)
            cid_chunks.append(ci)
            cd_chunks.append(cd)
        cids = jnp.concatenate(cid_chunks, axis=0)
        cdists = jnp.concatenate(cd_chunks, axis=0)
        graph = _select(cids, cdists)

    graph = add_reverse_edges(np.asarray(graph), M)
    return connectivity_repair(db, graph, entry, metric)


def connectivity_repair(db: jnp.ndarray, graph: np.ndarray, entry: int,
                        metric: str) -> np.ndarray:
    """NSG-style spanning pass: guarantee every node is reachable from the
    entry by linking each unreachable region to its nearest reachable node
    (replacing the victim's worst edge if it has no spare slot)."""
    import collections
    g = np.asarray(graph).copy()
    n, M = g.shape
    dbn = np.asarray(db)

    def reachable_set():
        seen = np.zeros(n, dtype=bool)
        dq = collections.deque([entry])
        seen[entry] = True
        while dq:
            u = dq.popleft()
            for v in g[u]:
                if v >= 0 and not seen[v]:
                    seen[v] = True
                    dq.append(int(v))
        return seen

    seen = reachable_set()
    guard = 0
    while not seen.all() and guard < n:
        guard += 1
        un = np.nonzero(~seen)[0]
        re = np.nonzero(seen)[0]
        # nearest (reachable, unreachable) pair under the metric, chunked
        best = (np.inf, -1, -1)
        for s in range(0, len(un), 512):
            u_blk = un[s:s + 512]
            if metric == "l2":
                d = (((dbn[re] ** 2).sum(1)[:, None]
                      + (dbn[u_blk] ** 2).sum(1)[None])
                     - 2.0 * dbn[re] @ dbn[u_blk].T)
            else:
                d = -(dbn[re] @ dbn[u_blk].T)
            ij = np.unravel_index(np.argmin(d), d.shape)
            if d[ij] < best[0]:
                best = (float(d[ij]), int(re[ij[0]]), int(u_blk[ij[1]]))
        _, r, u = best
        spare = np.nonzero(g[r] < 0)[0]
        slot = spare[0] if len(spare) else M - 1   # replace worst (last) edge
        g[r, slot] = u
        # flood-fill from u through the existing graph
        dq = collections.deque([u])
        seen[u] = True
        while dq:
            w = dq.popleft()
            for v in g[w]:
                if v >= 0 and not seen[v]:
                    seen[v] = True
                    dq.append(int(v))
    return g
