"""Vector quantization (paper §3.2, A4): PQ and SQ as pluggable modules.

The paper exposes quantization behind a standalone interface so algorithms
can be swapped without touching the search core; we mirror that:

  Quantizer.train(db)      -> state (codebooks / scales)
  Quantizer.encode(db)     -> codes
  Quantizer.query_tables(q)-> per-query operand passed to search() as the
                              "queries" array (the search loop is agnostic)
  Quantizer.make_dist_fn() -> DistFn consuming (tables, nbr_ids)

PQ distance is ADC (asymmetric distance computation): per query build an
(m, 256) lookup table of subspace distances; a database code (m,) uint8 then
costs m table reads. On TPU the LUT gather is computed either by
take_along_axis (ref) or the pq_adc Pallas kernel via one-hot contraction on
the MXU (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import QuantConfig


# --------------------------------------------------------------------------
# k-means (shared by PQ training)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(x: jnp.ndarray, k: int, iters: int, seed: int = 0) -> jnp.ndarray:
    """Lloyd's algorithm; returns (k, d) centroids. Empty clusters keep
    their previous centroid (standard fix)."""
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    init_idx = jax.random.choice(key, n, (k,), replace=n < k)
    cents = x[init_idx]

    def step(cents, _):
        d2 = (jnp.sum(x * x, 1, keepdims=True) + jnp.sum(cents * cents, 1)[None]
              - 2.0 * x @ cents.T)
        assign = jnp.argmin(d2, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), assign, num_segments=k)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts[:, None], 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


# --------------------------------------------------------------------------
# Product quantization
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PQState:
    codebooks: jnp.ndarray  # (m, 256, ds)
    m: int
    ds: int


def pq_train(db: jnp.ndarray, cfg: QuantConfig) -> PQState:
    n, d = db.shape
    m = cfg.pq_m
    assert d % m == 0, f"dim {d} not divisible by pq_m {m}"
    ds = d // m
    subs = db.reshape(n, m, ds).transpose(1, 0, 2)  # (m, n, ds)
    books = jnp.stack([
        kmeans(subs[j], 256, cfg.kmeans_iters, seed=cfg.seed + j)
        for j in range(m)
    ])
    return PQState(codebooks=books, m=m, ds=ds)


@functools.partial(jax.jit, static_argnames=())
def pq_encode(state_books: jnp.ndarray, db: jnp.ndarray) -> jnp.ndarray:
    """(n, d) -> (n, m) uint8 codes."""
    m, K, ds = state_books.shape
    n = db.shape[0]
    subs = db.reshape(n, m, ds)
    d2 = (jnp.sum(subs * subs, -1)[:, :, None]
          + jnp.sum(state_books * state_books, -1)[None]
          - 2.0 * jnp.einsum("nmd,mkd->nmk", subs, state_books))
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("metric",))
def pq_query_tables(state_books: jnp.ndarray, queries: jnp.ndarray, metric: str
                    ) -> jnp.ndarray:
    """Per-query ADC lookup tables, flattened to (Q, m*256).

    l2: LUT[j, c] = ||q_j - C[j, c]||^2  (sums to ||q - x_hat||^2)
    ip: LUT[j, c] = -<q_j, C[j, c]>      (sums to -<q, x_hat>)
    """
    m, K, ds = state_books.shape
    Q = queries.shape[0]
    qs = queries.reshape(Q, m, ds)
    if metric == "l2":
        lut = (jnp.sum(qs * qs, -1)[:, :, None]
               + jnp.sum(state_books * state_books, -1)[None]
               - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, state_books))
    else:
        lut = -jnp.einsum("qmd,mkd->qmk", qs, state_books)
    return lut.reshape(Q, m * K)


def pq_make_dist_fn(codes: jnp.ndarray, m: int, impl: str = "ref"):
    """DistFn over PQ codes. `tables` (the search "queries") is (Q, m*256)."""
    K = 256

    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(tables, nbr_ids):
            return kops.pq_adc(tables.reshape(tables.shape[0], m, K),
                               codes, nbr_ids)
        return fn

    def fn(tables, nbr_ids):
        Q, MB = tables.shape[0], nbr_ids.shape[1]
        lut = tables.reshape(Q, m, K)
        c = codes[jnp.maximum(nbr_ids, 0)]          # (Q, B, m) uint8
        g = jnp.take_along_axis(
            lut[:, None, :, :],                     # (Q, 1, m, K)
            c[..., None].astype(jnp.int32),         # (Q, B, m, 1)
            axis=-1)[..., 0]
        return jnp.sum(g, axis=-1)
    return fn


# --------------------------------------------------------------------------
# Scalar quantization (int8 per-dimension affine)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SQState:
    scale: jnp.ndarray   # (d,)
    zero: jnp.ndarray    # (d,)


def sq_train(db: jnp.ndarray) -> SQState:
    lo = jnp.min(db, axis=0)
    hi = jnp.max(db, axis=0)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    return SQState(scale=scale, zero=lo)


def sq_encode(state: SQState, db: jnp.ndarray) -> jnp.ndarray:
    return _sq_encode(state.scale, state.zero, db)


@jax.jit
def _sq_encode(scale: jnp.ndarray, zero: jnp.ndarray, db: jnp.ndarray
               ) -> jnp.ndarray:
    q = jnp.round((db - zero[None]) / scale[None])
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def sq_make_dist_fn(codes: jnp.ndarray, state: SQState, metric: str):
    """DistFn with on-the-fly dequantization (fused in the kernel path)."""
    from repro.core.distance import batched_one_to_many

    def fn(queries, nbr_ids):
        c = codes[jnp.maximum(nbr_ids, 0)].astype(jnp.float32)
        vecs = c * state.scale[None, None, :] + state.zero[None, None, :]
        return batched_one_to_many(queries, vecs, metric)
    return fn
