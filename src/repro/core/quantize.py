"""Vector quantization (paper §3.2, A4): PQ and SQ as pluggable modules.

The paper exposes quantization behind a standalone interface so algorithms
can be swapped without touching the search core; we mirror that:

  Quantizer.train(db)      -> state (codebooks / scales)
  Quantizer.encode(db)     -> codes
  Quantizer.query_tables(q)-> per-query operand passed to search() as the
                              "queries" array (the search loop is agnostic)
  Quantizer.make_dist_fn() -> DistFn consuming (tables, nbr_ids)

PQ distance is ADC (asymmetric distance computation): per query build an
(m, K) lookup table of subspace distances; a database code (m,) then costs m
table reads. On TPU the LUT gather is computed either by take_along_axis
(ref) or the pq_adc Pallas kernel via one-hot contraction on the MXU
(DESIGN.md §2).

Two PQ code widths (DESIGN.md §13):
  kind="pq"  — 8-bit codes, K=256 centroids/sub-codebook, one byte/code.
  kind="pq4" — 4-bit fast-scan codes, K=16, TWO codes packed per byte
               (low nibble = even subspace 2j, high nibble = odd 2j+1).
               The (m, 16) LUT is 16x smaller, so it stays resident in
               VMEM/registers during the scan; optionally the LUT is
               requantized to u8 per query (pq4_requant_lut) as in x86
               fast-scan, trading a bounded distance error (<= m*step/2)
               for byte-wide table arithmetic.

One extreme-compression codec (DESIGN.md §14):
  kind="bin" — 1 bit/dimension: a seeded random orthonormal rotation
               (QR of a Gaussian) followed by sign quantization, packed
               into ceil(d/32) uint32 words per vector. The first-pass
               distance is Hamming (XOR + popcount) between packed query
               and database codes; the RaBitQ-style estimator error is
               absorbed by overfetching SearchConfig.rescore_factor * k
               candidates and re-ranking them exactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import QUANT_KINDS, QuantConfig


# --------------------------------------------------------------------------
# k-means (shared by PQ training)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(x: jnp.ndarray, k: int, iters: int, seed: int = 0) -> jnp.ndarray:
    """Lloyd's algorithm; returns (k, d) centroids. Empty clusters keep
    their previous centroid (standard fix)."""
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    init_idx = jax.random.choice(key, n, (k,), replace=n < k)
    cents = x[init_idx]

    def step(cents, _):
        d2 = (jnp.sum(x * x, 1, keepdims=True) + jnp.sum(cents * cents, 1)[None]
              - 2.0 * x @ cents.T)
        assign = jnp.argmin(d2, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), assign, num_segments=k)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts[:, None], 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


# --------------------------------------------------------------------------
# Product quantization
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PQState:
    codebooks: jnp.ndarray  # (m, K, ds); K=256 for pq, 16 for pq4
    m: int
    ds: int

    @property
    def ksub(self) -> int:
        return self.codebooks.shape[1]


def pq_train(db: jnp.ndarray, cfg: QuantConfig) -> PQState:
    """Train per-subspace codebooks; K follows cfg.kind (256 or 16)."""
    n, d = db.shape
    m = cfg.pq_m
    assert d % m == 0, f"dim {d} not divisible by pq_m {m}"
    ds = d // m
    K = cfg.ksub if cfg.kind in ("pq", "pq4") else 256
    subs = db.reshape(n, m, ds).transpose(1, 0, 2)  # (m, n, ds)
    books = jnp.stack([
        kmeans(subs[j], K, cfg.kmeans_iters, seed=cfg.seed + j)
        for j in range(m)
    ])
    return PQState(codebooks=books, m=m, ds=ds)


@functools.partial(jax.jit, static_argnames=())
def pq_encode(state_books: jnp.ndarray, db: jnp.ndarray) -> jnp.ndarray:
    """(n, d) -> (n, m) uint8 codes."""
    m, K, ds = state_books.shape
    n = db.shape[0]
    subs = db.reshape(n, m, ds)
    d2 = (jnp.sum(subs * subs, -1)[:, :, None]
          + jnp.sum(state_books * state_books, -1)[None]
          - 2.0 * jnp.einsum("nmd,mkd->nmk", subs, state_books))
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("metric",))
def pq_query_tables(state_books: jnp.ndarray, queries: jnp.ndarray, metric: str
                    ) -> jnp.ndarray:
    """Per-query ADC lookup tables, flattened to (Q, m*256).

    l2: LUT[j, c] = ||q_j - C[j, c]||^2  (sums to ||q - x_hat||^2)
    ip: LUT[j, c] = -<q_j, C[j, c]>      (sums to -<q, x_hat>)
    """
    m, K, ds = state_books.shape
    Q = queries.shape[0]
    qs = queries.reshape(Q, m, ds)
    if metric == "l2":
        lut = (jnp.sum(qs * qs, -1)[:, :, None]
               + jnp.sum(state_books * state_books, -1)[None]
               - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, state_books))
    else:
        lut = -jnp.einsum("qmd,mkd->qmk", qs, state_books)
    return lut.reshape(Q, m * K)


def pq_make_dist_fn(codes: jnp.ndarray, m: int, impl: str = "ref"):
    """DistFn over PQ codes. `tables` (the search "queries") is (Q, m*256)."""
    K = 256

    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(tables, nbr_ids):
            return kops.pq_adc(tables.reshape(tables.shape[0], m, K),
                               codes, nbr_ids)
        return fn

    def fn(tables, nbr_ids):
        Q, MB = tables.shape[0], nbr_ids.shape[1]
        lut = tables.reshape(Q, m, K)
        c = codes[jnp.maximum(nbr_ids, 0)]          # (Q, B, m) uint8
        g = jnp.take_along_axis(
            lut[:, None, :, :],                     # (Q, 1, m, K)
            c[..., None].astype(jnp.int32),         # (Q, B, m, 1)
            axis=-1)[..., 0]
        return jnp.sum(g, axis=-1)
    return fn


# --------------------------------------------------------------------------
# 4-bit fast-scan product quantization (DESIGN.md §13)
# --------------------------------------------------------------------------
def pq4_pack(codes: jnp.ndarray) -> jnp.ndarray:
    """(n, m) 4-bit codes (values < 16) -> (n, m//2) uint8, two per byte.

    Byte j holds subspace 2j in the LOW nibble and 2j+1 in the HIGH nibble,
    so a SIMD lane reading byte j serves two adjacent LUT rows.
    """
    n, m = codes.shape
    assert m % 2 == 0, m
    c = codes.astype(jnp.uint8)
    return (c[:, 0::2] | (c[:, 1::2] << 4)).astype(jnp.uint8)


def pq4_unpack(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., m//2) packed bytes -> (..., m) int32 codes in [0, 16)."""
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1]
                                                + (2 * packed.shape[-1],))


def pq4_encode(state_books: jnp.ndarray, db: jnp.ndarray) -> jnp.ndarray:
    """(n, d) -> (n, m//2) uint8 nibble-packed codes (codebooks (m, 16, ds))."""
    assert state_books.shape[1] == 16, state_books.shape
    return pq4_pack(pq_encode(state_books, db))


def pq4_requant_lut(lut: jnp.ndarray) -> jnp.ndarray:
    """Fast-scan LUT requantization, applied per query.

    Each query's table is affinely mapped to u8 (step = (max-min)/255 over
    the whole (m, K) table) and mapped back, so every downstream consumer —
    ref gather, Pallas kernel, tests — sees exactly the distances a u8
    table walk would produce. The ADC sum error is bounded by m*step/2
    (each of the m reads is off by at most step/2); on real hardware the u8
    table is what lives in registers and this fold-back is free.

    lut: (Q, T) flattened tables. Returns same-shape f32.
    """
    lo = jnp.min(lut, axis=1, keepdims=True)
    hi = jnp.max(lut, axis=1, keepdims=True)
    step = jnp.maximum(hi - lo, 1e-12) / 255.0
    q = jnp.clip(jnp.round((lut - lo) / step), 0, 255)
    return q * step + lo


def pq4_query_tables(state_books: jnp.ndarray, queries: jnp.ndarray,
                     metric: str, lut_u8: bool = False) -> jnp.ndarray:
    """Per-query (m, 16) ADC tables, flattened to (Q, m*16).

    Same algebra as pq_query_tables (K=16); with lut_u8 the table goes
    through the fast-scan u8 requantization (pq4_requant_lut).
    """
    lut = pq_query_tables(state_books, queries, metric)
    return pq4_requant_lut(lut) if lut_u8 else lut


def pq4_make_dist_fn(packed: jnp.ndarray, m: int, impl: str = "ref"):
    """DistFn over nibble-packed PQ4 codes; `tables` is (Q, m*16)."""
    K = 16

    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(tables, nbr_ids):
            return kops.pq4_adc(tables.reshape(tables.shape[0], m, K),
                                packed, nbr_ids)
        return fn

    def fn(tables, nbr_ids):
        Q = tables.shape[0]
        lut = tables.reshape(Q, m, K)
        c = pq4_unpack(packed[jnp.maximum(nbr_ids, 0)])   # (Q, B, m) i32
        g = jnp.take_along_axis(lut[:, None, :, :], c[..., None], axis=-1)[..., 0]
        return jnp.sum(g, axis=-1)
    return fn


# --------------------------------------------------------------------------
# Scalar quantization (int8 per-dimension affine)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SQState:
    scale: jnp.ndarray   # (d,)
    zero: jnp.ndarray    # (d,)


def sq_train(db: jnp.ndarray) -> SQState:
    lo = jnp.min(db, axis=0)
    hi = jnp.max(db, axis=0)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    return SQState(scale=scale, zero=lo)


def sq_encode(state: SQState, db: jnp.ndarray) -> jnp.ndarray:
    return _sq_encode(state.scale, state.zero, db)


@jax.jit
def _sq_encode(scale: jnp.ndarray, zero: jnp.ndarray, db: jnp.ndarray
               ) -> jnp.ndarray:
    q = jnp.round((db - zero[None]) / scale[None])
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def sq_make_dist_fn(codes: jnp.ndarray, state: SQState, metric: str,
                    impl: str = "ref"):
    """DistFn with on-the-fly dequantization.

    impl="kernel" routes through the fused sq_gather_dist Pallas kernel
    (u8 rows gathered by scalar-prefetch, dequantized in-VMEM); impl="ref"
    is the jnp gather+dequant oracle. Historical bug: this function used to
    ignore `impl`, so dist_impl="kernel" SQ runs silently took — and were
    benchmarked as — the ref path under a ("sq", "kernel") cache key.
    """
    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(queries, nbr_ids):
            return kops.sq_gather_dist(queries, codes, state.scale,
                                       state.zero, nbr_ids, metric=metric)
        return fn

    from repro.core.distance import batched_one_to_many

    def fn(queries, nbr_ids):
        c = codes[jnp.maximum(nbr_ids, 0)].astype(jnp.float32)
        vecs = c * state.scale[None, None, :] + state.zero[None, None, :]
        return batched_one_to_many(queries, vecs, metric)
    return fn


# --------------------------------------------------------------------------
# 1-bit binary quantization (random-rotation sign codec, DESIGN.md §14)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class BinState:
    rot: jnp.ndarray    # (d, d) f32 orthonormal rotation (QR of a Gaussian)

    @property
    def dim(self) -> int:
        return self.rot.shape[0]

    @property
    def n_words(self) -> int:
        return -(-self.dim // 32)


def _random_rotation(d: int, seed: int) -> jnp.ndarray:
    """Orthonormal (d, d) rotation: QR of a seeded Gaussian, with the R
    diagonal sign-fixed so the factorization (and thus every code) is a
    deterministic function of the seed."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (d, d), jnp.float32)
    q, r = jnp.linalg.qr(g)
    s = jnp.sign(jnp.diagonal(r))
    return q * jnp.where(s == 0, 1.0, s)[None, :]


def pack_signs(bits: jnp.ndarray) -> jnp.ndarray:
    """(n, d) sign bits ({0,1}, any int/bool dtype) -> (n, ceil(d/32))
    uint32. Bit b of word w holds dimension 32*w + b; tail dimensions of
    the last word (d not a multiple of 32) are zero on BOTH query and
    database codes, so they XOR to 0 and never contribute to Hamming."""
    n, d = bits.shape
    nw = -(-d // 32)
    b = bits.astype(jnp.uint32)
    if nw * 32 != d:
        b = jnp.pad(b, ((0, 0), (0, nw * 32 - d)))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # disjoint bit positions: the uint32 sum is carry-free, i.e. an OR
    return jnp.sum(b.reshape(n, nw, 32) << shifts[None, None, :],
                   axis=-1, dtype=jnp.uint32)


def unpack_signs(packed: jnp.ndarray, d: int) -> jnp.ndarray:
    """(n, ceil(d/32)) uint32 -> (n, d) uint8 sign bits (pack_signs inverse)."""
    n, nw = packed.shape
    assert nw * 32 >= d, (nw, d)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(n, nw * 32)[:, :d].astype(jnp.uint8)


def bin_train(db: jnp.ndarray, cfg: QuantConfig) -> BinState:
    """"Training" is just drawing the rotation — data-independent, so the
    codec never needs retraining as the corpus changes."""
    return BinState(rot=_random_rotation(db.shape[1], cfg.seed))


@jax.jit
def _bin_encode(rot: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return pack_signs((x @ rot >= 0).astype(jnp.uint32))


def bin_encode(state: BinState, db: jnp.ndarray) -> jnp.ndarray:
    """(n, d) f32 -> (n, ceil(d/32)) uint32 packed sign codes."""
    return _bin_encode(state.rot, db)


def bin_query_codes(state: BinState, queries: jnp.ndarray) -> jnp.ndarray:
    """Query-side operand passed to search() as the "queries" array: the
    SAME rotation+sign+pack as the database side (symmetric Hamming)."""
    return _bin_encode(state.rot, queries)


def bin_make_dist_fn(codes: jnp.ndarray, impl: str = "ref"):
    """DistFn over packed bin codes; `qcodes` (the search "queries") is
    (Q, nw) uint32. Distances are exact integer Hamming counts in f32."""
    if impl == "kernel":
        from repro.kernels import ops as kops

        def fn(qcodes, nbr_ids):
            return kops.bin_dist(qcodes, codes, nbr_ids)
        return fn

    from repro.kernels.ref import bin_dist_ref

    def fn(qcodes, nbr_ids):
        return bin_dist_ref(qcodes, codes, nbr_ids)
    return fn


# --------------------------------------------------------------------------
# Quant-kind registry (sweeps) and the code-size accounting they report
# --------------------------------------------------------------------------
def quant_variants(pq_m: int = 16) -> dict:
    """Named QuantConfig kwargs for every quantization variant — THE list
    sweeps enumerate (core/tune.py, benchmarks/ablation.py), so a new kind
    added here (and to types.QUANT_KINDS, which tests assert this registry
    covers) appears in every sweep automatically. pq_m must divide the
    dataset dim; "bin" and "sq" ignore it."""
    return {
        "full": dict(kind="none"),
        "pq8": dict(kind="pq", pq_m=pq_m),
        "pq4": dict(kind="pq4", pq_m=pq_m),
        "pq4+u8lut": dict(kind="pq4", pq_m=pq_m, pq4_lut_u8=True),
        "sq": dict(kind="sq"),
        "bin": dict(kind="bin"),
    }


# The IVF-capable subset of the registry: build_ivf has explicit codecs
# only for these — any other kind silently trains the default 8-bit PQ
# fine stage, so an "ivf-sq" sweep row would really measure ivf-pq.
# THE list the benchmarks derive their ivf-* rows from
# (benchmarks/qps_recall.py); kbest-lint asserts it stays a subset of
# types.QUANT_KINDS.
IVF_QUANT_KINDS = ("pq", "pq4", "bin")


def code_bytes_per_vector(idx) -> int:
    """Stored code bytes per database vector (the A4 memory axis), dtype-
    aware: pq/pq4/sq codes are uint8 (1 byte/element) but bin codes are
    uint32 words (4 bytes/element). Takes a KBest (duck-typed)."""
    for arr in (getattr(idx, "ivf", None) and idx.ivf.list_codes,
                getattr(idx, "bin_codes", None),
                getattr(idx, "pq_codes", None),
                getattr(idx, "sq_codes", None)):
        if arr is not None:
            return int(arr.shape[-1]) * arr.dtype.itemsize
    return 4 * int(idx.db.shape[-1])            # f32 full vectors
