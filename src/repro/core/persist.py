"""Crash-safe persistence primitives (DESIGN.md §17).

A save interrupted by a crash (OOM kill, power loss, deploy rollover) must
never leave a loadable-but-wrong index behind: the serving tier would
happily answer queries from garbage. The protocol here gives every save
two properties:

  atomicity  — each artifact is written to `<name>.tmp`, fsync'd, and
               `os.replace`d into place; readers only ever see the old
               bytes or the new bytes, never a torn write.
  detection  — the JSON sidecar carries a crc32 per saved array, and is
               itself written (atomically) AFTER the array file. The
               sidecar is therefore the commit point: a crash between the
               two renames leaves new arrays under an old sidecar, which
               `load()` rejects with `IndexCorruptError` instead of
               deserializing a mismatched pair.

Sharded saves extend the same idea one level up: shards commit first
(each with the single-index protocol), then the `.sharded.json` manifest
— embedding a crc32 of every shard's sidecar bytes — commits the whole
mesh last (the manifest-last protocol of `ShardedKBest.save`).

`checkpoint(step)` names every kill point in the protocol; the fault
harness (`serve/faults.py: crash_at / trace_steps`) hooks it to kill a
save at each step and assert load sees old-or-error, never garbage
(tests/test_crashsafe.py).
"""
from __future__ import annotations

import os
import zlib
from pathlib import Path
from typing import Callable, Dict, Mapping, Optional

import numpy as np


class IndexCorruptError(RuntimeError):
    """A persisted index failed validation (truncation, checksum mismatch,
    torn sidecar, partial sharded save). Never returned as data — load()
    raises instead of deserializing a suspect artifact."""


# ------------------------------------------------------------ crash hook
_crash_hook: Optional[Callable[[str], None]] = None


def set_crash_hook(fn: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with None) the fault-injection hook. Test-only:
    production saves run with no hook and checkpoint() is a no-op."""
    global _crash_hook
    _crash_hook = fn


def checkpoint(step: str) -> None:
    """Named kill point inside the save protocol. The hook may raise to
    simulate a crash at exactly this step."""
    if _crash_hook is not None:
        _crash_hook(step)


# ---------------------------------------------------------- atomic write
def _fsync_dir(d: Path) -> None:
    # directory fsync makes the rename itself durable; best-effort because
    # not every filesystem (or sandbox) grants O_RDONLY on directories
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write(path: Path, data: bytes, label: str) -> None:
    """tmp + fsync + rename. `label` names this artifact's kill points:
    `{label}.begin` (nothing written), `{label}.staged` (tmp durable,
    final untouched), `{label}.committed` (rename done)."""
    path = Path(path)
    checkpoint(f"{label}.begin")
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    checkpoint(f"{label}.staged")
    os.replace(tmp, path)
    _fsync_dir(path.parent)
    checkpoint(f"{label}.committed")


# ------------------------------------------------------------- checksums
def array_checksums(arrs: Mapping[str, np.ndarray]) -> Dict[str, int]:
    """crc32 over each array's raw bytes (C-contiguous view)."""
    return {k: int(zlib.crc32(np.ascontiguousarray(v).tobytes()))
            for k, v in arrs.items()}


def file_crc32(path: Path) -> int:
    return int(zlib.crc32(Path(path).read_bytes()))


def save_arrays(path: Path, arrs: Mapping[str, np.ndarray],
                label: str) -> Dict[str, int]:
    """Atomically write an .npz of `arrs` to `path`; returns the per-array
    checksums for the caller's sidecar."""
    import io
    buf = io.BytesIO()
    np.savez_compressed(buf, **arrs)
    atomic_write(path, buf.getvalue(), label)
    return array_checksums(arrs)


def load_arrays(path: Path,
                checksums: Optional[Mapping[str, int]]) -> Dict[str, np.ndarray]:
    """Read an .npz back, failing loudly: any read/parse error (truncation,
    torn zip) and any checksum/name mismatch raises IndexCorruptError.
    `checksums=None` skips verification (legacy pre-§17 sidecars)."""
    try:
        with np.load(path) as z:
            data = {k: np.asarray(z[k]) for k in z.files}
    except IndexCorruptError:
        raise
    except Exception as e:                     # zipfile/pickle/np errors
        raise IndexCorruptError(
            f"unreadable index arrays at {path}: {e!r}") from e
    if checksums is not None:
        if set(data) != set(checksums):
            raise IndexCorruptError(
                f"array set mismatch at {path}: sidecar lists "
                f"{sorted(checksums)}, file holds {sorted(data)} — "
                f"torn save (arrays and sidecar from different commits)")
        for name, crc in array_checksums(data).items():
            want = int(checksums[name])
            if crc != want:
                raise IndexCorruptError(
                    f"checksum mismatch for array '{name}' at {path}: "
                    f"crc32 {crc} != sidecar {want}")
    return data
