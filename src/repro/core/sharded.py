"""ShardedKBest — shard-per-device composition of KBest indexes (DESIGN.md §12).

The paper's KBest is single-node; at pod scale the standard architecture
(the one Milvus deploys KBest into, and the one KScaNN scales to billions
of vectors) is shard-per-device + merge:

  * the corpus is split into P contiguous row ranges ("shards");
  * each shard is built as an INDEPENDENT single-shard KBest — its own
    proximity graph + medoid entry points (graph family) or its own coarse
    centroids + inverted lists (IVF family), and its own PQ/SQ codebooks —
    so no cross-shard edges or lists exist;
  * a query runs the full shard-local pipeline on every shard, including
    the quantized first pass (pq8 / pq4 / sq ADC, or the bin codec's
    XOR+popcount Hamming + rescore — DESIGN.md §14) and the SHARD-LOCAL
    exact re-rank, then the per-shard exact top-k are merged into the
    global top-k (one O(P·k) reduction over exact distances).

Recall of a sharded index is >= the single-shard index at equal per-shard
L, because each shard runs its own full traversal (more total distance
evaluations buy the recall; the QPS/recall trade is measured in
benchmarks/scaling.py and asserted in tests/test_sharded.py). With P = 1
the composition is bit-identical to plain KBest: the merge of one shard's
sorted top-k is the identity.

The SearchConfig is applied per shard verbatim — a beam_width W
(DESIGN.md §2) means every shard's traversal expands W candidates per
lockstep iteration, so the merged `iters` (critical path) drops ~W× across
the whole mesh and P=1 beam results stay bit-identical to plain KBest.

Stats-merge semantics (`with_stats=True`): per-shard `n_hops` and `n_dist`
are SUMMED per query (total work across the mesh, keeping the
dists-per-query telemetry in the same cross-family units as DESIGN.md §4);
`early_terminated` is the logical AND over shards (a merged lane counts as
early-terminated only when every shard's traversal fired Eq. 3);
`iters` is the max over shards (critical-path lockstep iterations). All
reduce to the single-index stats at P = 1.

Ids returned to the caller are GLOBAL row ids into the original add()
matrix: shard s translates its local results by `offsets[s]` (each shard's
internal reorder permutation is already undone inside KBest._search_impl).

Execution: the Python loop over shards unrolls under one jit trace (the
serving engine compiles it as a single XLA program per shape bucket — the
engine's cache key carries `IndexConfig.n_shards` as the mesh shape). For
a physical device mesh, `build_sharded_search`/`make_sharded_arrays` below
keep the `jax.shard_map` lowering of the full-precision graph path, where
the same shard-local-search + all-gather + top-k merge runs one shard per
device ((16, 16) and (2, 16, 16) production meshes in the dry-run, the
1-device CPU mesh in tests).

Persistence: `save(path)` writes each shard through `KBest.save` as
`<path>.shard<s>[.npz/.json]` plus ONE `<path>.sharded.json` sidecar
(n_shards, row offsets, full config); `load` reconstructs every shard.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import index as index_mod
from repro.core import persist
from repro.core import search as search_mod
from repro.core.index import (KBest, _config_from_dict, _config_to_dict,
                              mask_padded_lanes, prep_queries,
                              resolve_search_cfg)
from repro.core.types import IndexConfig, SearchConfig


def shard_bounds(n: int, n_shards: int) -> np.ndarray:
    """(P+1,) row offsets of the contiguous shard split.

    The first n % P shards take one extra row, so ANY n >= P shards without
    padding or truncation — uneven corpora are first-class (the device-mesh
    layout path, which does need equal shards, pads instead: see
    make_sharded_arrays)."""
    assert n >= n_shards >= 1, (n, n_shards)
    base, rem = divmod(n, n_shards)
    sizes = np.full(n_shards, base, np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def merge_stats(per_shard: Sequence[search_mod.SearchStats]
                ) -> search_mod.SearchStats:
    """Fold per-shard stats into one merged SearchStats (semantics in the
    module docstring; identity for a single shard)."""
    return search_mod.SearchStats(
        n_hops=functools.reduce(jnp.add, [s.n_hops for s in per_shard]),
        n_dist=functools.reduce(jnp.add, [s.n_dist for s in per_shard]),
        early_terminated=functools.reduce(
            jnp.logical_and, [s.early_terminated for s in per_shard]),
        iters=functools.reduce(jnp.maximum, [s.iters for s in per_shard]),
    )


class ShardedKBest:
    """KBest's API surface over a mesh of independent per-shard indexes.

    Mirrors the facade of core/index.py (add / search / search_padded /
    save / load, plus the `_resolve_cfg` hook the serving engine keys on),
    so `SearchEngine` serves it unchanged.
    """

    def __init__(self, config: IndexConfig, n_shards: Optional[int] = None):
        if n_shards is not None and n_shards != config.n_shards:
            config = dataclasses.replace(config, n_shards=n_shards)
        self.config = config
        self.shards: List[KBest] = []
        self.offsets: Optional[np.ndarray] = None   # (P+1,) global row offsets

    # ---------------------------------------------------------- properties
    @property
    def n_shards(self) -> int:
        return self.config.n_shards

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        """Flat "shards" view of the mesh (the engine cache-key component)."""
        return (self.config.n_shards,)

    @property
    def db(self) -> Optional[jnp.ndarray]:
        """Shard 0's vectors — non-None iff built (the duck-type handle the
        serving engine uses for the built-index assert and query dim)."""
        return self.shards[0].db if self.shards else None

    @property
    def n_total(self) -> int:
        return int(self.offsets[-1]) if self.offsets is not None else 0

    # ------------------------------------------------------------------ add
    def add(self, x: np.ndarray) -> "ShardedKBest":
        """Split rows into n_shards contiguous ranges and build each as an
        independent single-shard KBest (own entry points / centroids /
        codebooks)."""
        x = np.asarray(x, dtype=np.float32)
        assert x.ndim == 2 and x.shape[1] == self.config.dim, x.shape
        self.offsets = shard_bounds(x.shape[0], self.config.n_shards)
        shard_cfg = dataclasses.replace(self.config, n_shards=1)
        self.shards = [
            KBest(shard_cfg).add(x[self.offsets[s]:self.offsets[s + 1]])
            for s in range(self.config.n_shards)]
        return self

    # --------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: Optional[int] = None,
               search_cfg: Optional[SearchConfig] = None,
               with_stats: bool = False):
        """Global top-k over every shard. Same signature/returns as
        KBest.search; ids are global row ids of the add() matrix."""
        assert self.shards, "call add() first"
        scfg = self._resolve_cfg(k, search_cfg)
        dists, ids, stats = self._search_impl(
            prep_queries(self.config, queries), scfg, valid_mask=None,
            with_stats=with_stats)
        if with_stats:
            return dists, ids, stats
        return dists, ids

    def search_padded(self, queries: np.ndarray, valid_mask: np.ndarray,
                      k: Optional[int] = None,
                      search_cfg: Optional[SearchConfig] = None,
                      with_stats: bool = False):
        """Shape-stable padded-batch search (the serving entry point) —
        KBest.search_padded semantics over the sharded mesh: padded lanes
        start inactive in EVERY shard's traversal and come back as
        (+inf, -1) with zeroed merged stats."""
        assert self.shards, "call add() first"
        scfg = self._resolve_cfg(k, search_cfg)
        vm = jnp.asarray(valid_mask, dtype=bool)
        dists, ids, stats = self._search_impl(
            prep_queries(self.config, queries), scfg, valid_mask=vm,
            with_stats=with_stats)
        dists, ids, stats = mask_padded_lanes(vm, dists, ids, stats)
        if with_stats:
            return dists, ids, stats
        return dists, ids

    def _resolve_cfg(self, k: Optional[int],
                     search_cfg: Optional[SearchConfig]) -> SearchConfig:
        return resolve_search_cfg(self.config, k, search_cfg)

    def _search_impl(self, q: jnp.ndarray, scfg: SearchConfig,
                     valid_mask: Optional[jnp.ndarray], with_stats: bool):
        """Shard-local searches (quantized first pass + shard-local exact
        re-rank, all inside KBest._search_impl) -> global-id translation ->
        cross-shard exact top-k merge. Pure jax ops given concrete configs,
        so the serving engine traces the whole mesh as one program."""
        k = scfg.k
        per_d, per_i, per_s = [], [], []
        for s, shard in enumerate(self.shards):
            d, i, st = shard._search_impl(
                q, scfg, valid_mask=valid_mask, with_stats=with_stats)
            off = int(self.offsets[s])
            per_d.append(d)
            per_i.append(jnp.where(i >= 0, i + off, -1))
            per_s.append(st)
        if len(self.shards) == 1:
            # single shard: the merge is the identity — skip the top-k so
            # P=1 is bit-identical to KBest by construction
            return per_d[0], per_i[0], (merge_stats(per_s)
                                        if with_stats else None)
        all_d = jnp.concatenate(per_d, axis=1)          # (Q, P*k)
        all_i = jnp.concatenate(per_i, axis=1)
        neg, pos = jax.lax.top_k(-all_d, k)
        dists = -neg
        ids = jnp.take_along_axis(all_i, pos, axis=1)
        return dists, ids, (merge_stats(per_s) if with_stats else None)

    # ------------------------------------------------------------ save/load
    @staticmethod
    def _shard_path(path: str, s: int) -> str:
        return f"{path}.shard{s}"

    def save(self, path: str) -> None:
        """Per-shard artifacts (KBest.save each, atomic + checksummed) with
        the `.sharded.json` manifest written LAST as the commit point
        (DESIGN.md §17). The manifest embeds a crc32 of every shard's
        sidecar bytes, so a crash that leaves new shards under an old
        manifest (or vice versa) is a detectable partial save, not a
        loadable mixed-generation mesh."""
        assert self.shards, "call add() first"
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        for s, shard in enumerate(self.shards):
            shard.save(self._shard_path(path, s), _label=f"shard{s}")
        shard_meta_crc = {
            str(s): persist.file_crc32(
                index_mod._meta_path(Path(self._shard_path(path, s))))
            for s in range(len(self.shards))}
        meta = {"n_shards": self.config.n_shards,
                "offsets": np.asarray(self.offsets).tolist(),
                "config": _config_to_dict(self.config),
                "format": 2,
                "shard_meta_crc": shard_meta_crc}
        persist.atomic_write(Path(str(p) + ".sharded.json"),
                             json.dumps(meta).encode(), "manifest")

    @classmethod
    def load(cls, path: str) -> "ShardedKBest":
        """Manifest-first load: a manifest whose per-shard sidecar crc32s
        disagree with the shard files on disk means the save that wrote
        them never committed — raise persist.IndexCorruptError rather than
        assembling shards from different save generations."""
        mp = Path(str(path) + ".sharded.json")
        try:
            meta = json.loads(mp.read_text())
        except FileNotFoundError:
            raise
        except Exception as e:
            raise persist.IndexCorruptError(
                f"unreadable sharded manifest at {mp}: {e!r}") from e
        crcs = meta.get("shard_meta_crc")   # absent on pre-§17 manifests
        if crcs is not None:
            for s in range(meta["n_shards"]):
                sp = index_mod._meta_path(Path(cls._shard_path(path, s)))
                try:
                    got = persist.file_crc32(sp)
                except FileNotFoundError as e:
                    raise persist.IndexCorruptError(
                        f"manifest names shard {s} but its sidecar {sp} "
                        f"is missing (partial sharded save)") from e
                if got != int(crcs[str(s)]):
                    raise persist.IndexCorruptError(
                        f"shard {s} sidecar {sp} does not match the "
                        f"manifest (crc32 {got} != {crcs[str(s)]}) — "
                        f"partial sharded save")
        cfg = _config_from_dict(meta["config"])
        idx = cls(cfg, n_shards=meta["n_shards"])
        idx.offsets = np.asarray(meta["offsets"], dtype=np.int64)
        idx.shards = [KBest.load(idx._shard_path(path, s))
                      for s in range(meta["n_shards"])]
        return idx


# --------------------------------------------------------------------------
# Device-mesh lowering of the sharded full-precision graph path (absorbed
# from the old core/distributed.py). ShardedKBest above is the subsystem —
# device-count agnostic, quantization-aware, engine-servable; this
# shard_map path is the physical-mesh execution shape the dry-run lowers
# for the (16, 16) / (2, 16, 16) production meshes, and shares the same
# local-search + all-gather + global-top-k merge algebra.
# --------------------------------------------------------------------------

def mesh_size(mesh: Mesh) -> int:
    out = 1
    for a in mesh.axis_names:
        out *= mesh.shape[a]
    return out


def build_sharded_search(mesh: Mesh, cfg: SearchConfig, metric: str,
                         n_local: int):
    """Returns a jit'd fn(db, graph, entries, queries) -> (dists, ids).

    db:      (P*n_local, d) row-sharded over the flattened mesh
    graph:   (P*n_local, M) sharded likewise, *local* ids in [0, n_local)
    entries: (P,) i32 per-shard entry points (local ids)
    queries: (Q, d) replicated
    Output:  (Q, k) replicated global top-k; ids are GLOBAL row ids.
    """
    axes = tuple(mesh.axis_names)
    row_spec = P(axes)           # dim0 sharded over every axis, flattened
    rep = P()
    p_tot = mesh_size(mesh)

    def local_search(db_l, graph_l, entry_l, queries):
        dist_fn = search_mod.make_dist_fn(db_l, metric, cfg.dist_impl)
        dists, ids, _ = search_mod.search(
            graph_l, queries, entry_l, dist_fn=dist_fn, cfg=cfg,
            n_total=n_local)
        # translate local -> global ids using this device's linear index
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        gids = jnp.where(ids >= 0, ids + idx * n_local, -1)
        # gather every shard's candidates and reduce to a global top-k
        all_d = jax.lax.all_gather(dists, axes)   # (P, Q, k)
        all_i = jax.lax.all_gather(gids, axes)
        Q, k = dists.shape
        all_d = all_d.reshape(p_tot, Q, k).transpose(1, 0, 2).reshape(Q, p_tot * k)
        all_i = all_i.reshape(p_tot, Q, k).transpose(1, 0, 2).reshape(Q, p_tot * k)
        neg, pos = jax.lax.top_k(-all_d, k)
        return -neg, jnp.take_along_axis(all_i, pos, axis=1)

    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec, rep),
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(fn)


def pad_to_shard_boundary(db: np.ndarray, graph: np.ndarray, n_shards: int
                          ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad (db, graph) rows up to n_local * P with masked sentinel rows.

    LAYOUT CONTRACT: the device-mesh path owns equal blocks — shard s is
    rows [s*n_local, (s+1)*n_local) with n_local = ceil(n / P) — so an
    uneven corpus is only representable as "every shard full except the
    LAST, which is tail-short". Appending sentinels at the global end
    completes exactly that layout; data split any other way (e.g.
    ShardedKBest's shard_bounds puts the remainder on the FIRST shards)
    must be re-laid-out into n_local blocks before calling this, or rows
    past the first short shard land on the wrong device.

    The sentinels are a zero vector with an all(-1) (edgeless) graph row.
    They are unreachable by construction: a caller's per-shard graph only
    references REAL local ids and the per-shard entry points must too, so
    a sentinel can never be seeded, expanded, or surface in the merged
    top-k. Returns (db_padded, graph_padded, n_local)."""
    db = np.asarray(db)
    graph = np.asarray(graph)
    n = db.shape[0]
    assert graph.shape[0] == n, (db.shape, graph.shape)
    n_local = -(-n // n_shards)
    pad = n_local * n_shards - n
    if pad:
        db = np.concatenate(
            [db, np.zeros((pad, db.shape[1]), db.dtype)], axis=0)
        graph = np.concatenate(
            [graph, np.full((pad, graph.shape[1]), -1, graph.dtype)], axis=0)
    return db, graph, n_local


def make_sharded_arrays(mesh: Mesh, db, graph, entries, queries):
    """device_put with the canonical shardings used by build_sharded_search.

    Uneven corpora (n % P != 0) are padded to the shard boundary with
    masked sentinel rows (pad_to_shard_boundary, whose tail-short LAYOUT
    CONTRACT applies) BEFORE placement — the old behavior handed jax a
    non-divisible dim 0, which either errored or misaligned every shard
    past the first remainder row. The real-row round-trip assert is a
    cheap sanity check that the logical array survived placement intact;
    it cannot detect a caller violating the layout contract (placement
    never reorders logical rows)."""
    axes = tuple(mesh.axis_names)
    p_tot = mesh_size(mesh)
    db = np.asarray(db)
    graph = np.asarray(graph)
    entries = np.asarray(entries)
    assert entries.shape[0] == p_tot, \
        f"need one entry point per shard: {entries.shape[0]} != {p_tot}"
    n = db.shape[0]
    db_p, graph_p, _ = pad_to_shard_boundary(db, graph, p_tot)
    row = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    out = (jax.device_put(db_p, row), jax.device_put(graph_p, row),
           jax.device_put(entries, row), jax.device_put(queries, rep))
    assert np.array_equal(np.asarray(out[0])[:n], db), "db round-trip"
    assert np.array_equal(np.asarray(out[1])[:n], graph), "graph round-trip"
    return out
