"""KBestIndex — the user-facing API (paper §4, Table 2).

    index = KBest(config)          # parameter preparation
    index.add(x)                   # index construction (build pipeline)
    d, i = index.search(q, k)      # query processing
    index.save(path) / KBest.load(path)

Build pipeline (DESIGN.md §3): kNN graph (brute / NN-descent) -> edge
selection -> F rounds of 2-hop refinement (A1) -> reverse-edge fill ->
graph reordering (A2) -> optional PQ/SQ training+encoding (A4) -> medoid
entry point. Search runs the batched traversal of core.search with early
termination (A3); quantized searches re-rank the top candidates with exact
distances (standard ADC + re-rank).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import quantize as qz
from repro.core import reorder as reorder_mod
from repro.core import search as search_mod
from repro.core.distance import normalize, pairwise
from repro.core.refine import refine_graph
from repro.core.types import IndexConfig, SearchConfig


class KBest:
    def __init__(self, config: IndexConfig):
        self.config = config
        self.db: Optional[jnp.ndarray] = None        # (n, d) f32 (normalized if cosine)
        self.graph: Optional[jnp.ndarray] = None     # (n, M) i32
        self.entry: int = 0
        self.order: Optional[np.ndarray] = None      # new->old id map
        # quantization state
        self.pq: Optional[qz.PQState] = None
        self.pq_codes: Optional[jnp.ndarray] = None
        self.sq: Optional[qz.SQState] = None
        self.sq_codes: Optional[jnp.ndarray] = None
        self._dist_fns = {}

    # ------------------------------------------------------------------ add
    def add(self, x: np.ndarray) -> "KBest":
        cfg = self.config
        b = cfg.build
        x = jnp.asarray(x, dtype=jnp.float32)
        assert x.ndim == 2 and x.shape[1] == cfg.dim, x.shape
        if cfg.metric == "cosine":
            x = normalize(x)
        metric = "ip" if cfg.metric == "cosine" else cfg.metric

        knn_ids, knn_dists = build_mod.build_knn(
            x, b.knn_k, metric, builder=b.builder,
            rounds=b.nn_descent_rounds, sample=b.nn_descent_sample, seed=b.seed)

        entry = build_mod.medoid(x, metric)
        graph = refine_graph(
            x, knn_ids, knn_dists, M=b.M, rule=b.select_rule, metric=metric,
            alpha=b.alpha, ssg_angle_deg=b.ssg_angle_deg,
            iters=b.refine_iters, cand_cap=b.refine_cands,
            entry=entry, search_L=b.search_L, search_passes=b.search_passes)

        if b.reorder != "none":
            weights = np.asarray(_edge_weights(x, graph, metric))
            if b.reorder == "mst":
                order = reorder_mod.mst_reorder(np.asarray(graph), weights, entry)
            elif b.reorder == "cm":
                order = reorder_mod.cuthill_mckee(np.asarray(graph), entry)
            else:
                raise ValueError(b.reorder)
            db2, g2, new_of_old = reorder_mod.apply_order(
                order, np.asarray(x), np.asarray(graph))
            x, graph = jnp.asarray(db2), jnp.asarray(g2)
            entry = int(new_of_old[entry])
            self.order = order

        self.db, self.graph, self.entry = x, jnp.asarray(graph), entry

        q = cfg.quant
        if q.kind == "pq":
            self.pq = qz.pq_train(x, q)
            self.pq_codes = qz.pq_encode(self.pq.codebooks, x)
        elif q.kind == "sq":
            self.sq = qz.sq_train(x)
            self.sq_codes = qz.sq_encode(self.sq, x)
        return self

    # --------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: Optional[int] = None,
               search_cfg: Optional[SearchConfig] = None,
               with_stats: bool = False):
        """Top-k search. queries: (Q, d). Returns (dists, ids[, stats])."""
        assert self.db is not None, "call add() first"
        cfg = self.config
        scfg = search_cfg or cfg.search
        if k is not None and k != scfg.k:
            scfg = dataclasses.replace(scfg, k=k)
        metric = "ip" if cfg.metric == "cosine" else cfg.metric

        q = jnp.asarray(queries, dtype=jnp.float32)
        if cfg.metric == "cosine":
            q = normalize(q)

        n = self.db.shape[0]
        entry_ids = self._entry_ids(scfg.n_entries, n)
        quant = cfg.quant.kind

        if quant == "pq":
            tables = qz.pq_query_tables(self.pq.codebooks, q, metric)
            dist_fn = self._get_dist_fn("pq", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, tables, entry_ids, dist_fn=dist_fn, cfg=_widen(scfg),
                n_total=n)
            dists, ids = self._rerank(q, ids, metric, scfg.k, cfg.quant.rerank)
        elif quant == "sq":
            dist_fn = self._get_dist_fn("sq", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, q, entry_ids, dist_fn=dist_fn, cfg=_widen(scfg),
                n_total=n)
            dists, ids = self._rerank(q, ids, metric, scfg.k, cfg.quant.rerank)
        else:
            dist_fn = self._get_dist_fn("full", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, q, entry_ids, dist_fn=dist_fn, cfg=scfg, n_total=n)

        # translate internal (post-reorder) ids back to the user's add() ids
        if self.order is not None:
            order = jnp.asarray(self.order, dtype=jnp.int32)
            ids = jnp.where(ids >= 0, order[jnp.maximum(ids, 0)], -1)

        if with_stats:
            return dists, ids, stats
        return dists, ids

    def _entry_ids(self, n_entries: int, n: int) -> jnp.ndarray:
        """Medoid + deterministic strided seeds: cheap cluster coverage for
        the lockstep search (the paper uses a random-or-fixed entry; multiple
        entries are the batched equivalent of per-thread random entries)."""
        e = max(1, min(n_entries, n))
        extra = (self.entry + (jnp.arange(1, e, dtype=jnp.int32)
                               * jnp.int32(max(1, n // e)))) % n
        return jnp.concatenate([jnp.array([self.entry], jnp.int32), extra])

    def _get_dist_fn(self, kind: str, impl: str):
        key = (kind, impl)
        if key not in self._dist_fns:
            metric = "ip" if self.config.metric == "cosine" else self.config.metric
            if kind == "full":
                fn = search_mod.make_dist_fn(self.db, metric, impl)
            elif kind == "pq":
                fn = qz.pq_make_dist_fn(self.pq_codes, self.pq.m, impl)
            elif kind == "sq":
                fn = qz.sq_make_dist_fn(self.sq_codes, self.sq, metric)
            else:
                raise ValueError(kind)
            self._dist_fns[key] = fn
        return self._dist_fns[key]

    def _rerank(self, q, ids, metric, k, rerank):
        """Exact re-rank of the quantized search's top candidates."""
        r = rerank if rerank > 0 else min(4 * k, ids.shape[1])
        r = min(r, ids.shape[1])
        cand = ids[:, :r]
        vecs = self.db[jnp.maximum(cand, 0)]
        from repro.core.distance import batched_one_to_many
        d = batched_one_to_many(q, vecs, metric)
        d = jnp.where(cand >= 0, d, jnp.inf)
        neg, pos = jax.lax.top_k(-d, k)
        return -neg, jnp.take_along_axis(cand, pos, axis=1)

    # ------------------------------------------------------------ save/load
    def save(self, path: str) -> None:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        arrs = {"db": np.asarray(self.db), "graph": np.asarray(self.graph)}
        if self.order is not None:
            arrs["order"] = np.asarray(self.order)
        if self.pq is not None:
            arrs["pq_codebooks"] = np.asarray(self.pq.codebooks)
            arrs["pq_codes"] = np.asarray(self.pq_codes)
        if self.sq is not None:
            arrs["sq_scale"] = np.asarray(self.sq.scale)
            arrs["sq_zero"] = np.asarray(self.sq.zero)
            arrs["sq_codes"] = np.asarray(self.sq_codes)
        np.savez_compressed(p, **arrs)
        meta = {"entry": self.entry,
                "config": _config_to_dict(self.config)}
        p.with_suffix(".json").write_text(json.dumps(meta))

    @classmethod
    def load(cls, path: str) -> "KBest":
        p = Path(path)
        meta = json.loads(p.with_suffix(".json").read_text())
        cfg = _config_from_dict(meta["config"])
        idx = cls(cfg)
        with np.load(p if p.suffix == ".npz" else str(p) + ".npz") as z:
            idx.db = jnp.asarray(z["db"])
            idx.graph = jnp.asarray(z["graph"])
            if "pq_codebooks" in z:
                books = jnp.asarray(z["pq_codebooks"])
                idx.pq = qz.PQState(books, books.shape[0], books.shape[2])
                idx.pq_codes = jnp.asarray(z["pq_codes"])
            if "sq_scale" in z:
                idx.sq = qz.SQState(jnp.asarray(z["sq_scale"]),
                                    jnp.asarray(z["sq_zero"]))
                idx.sq_codes = jnp.asarray(z["sq_codes"])
            if "order" in z:
                idx.order = np.asarray(z["order"])
        idx.entry = int(meta["entry"])
        return idx


def _widen(scfg: SearchConfig) -> SearchConfig:
    """Quantized first-pass searches return their whole (wide) queue so the
    exact re-rank has at least 4k candidates to work with."""
    want = max(scfg.L, 4 * scfg.k)
    return dataclasses.replace(scfg, L=want, k=want)


def _edge_weights(db: jnp.ndarray, graph: jnp.ndarray, metric: str) -> jnp.ndarray:
    from repro.core.refine import _chunk_dists
    n = graph.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)
    out = []
    for s in range(0, n, 1024):
        e = min(s + 1024, n)
        out.append(_chunk_dists(db, rows[s:e], graph[s:e], metric))
    w = jnp.concatenate(out, axis=0)
    return jnp.where(jnp.isfinite(w), w, 0.0)


def _config_to_dict(cfg: IndexConfig) -> dict:
    return dataclasses.asdict(cfg)


def _config_from_dict(d: dict) -> IndexConfig:
    from repro.core.types import BuildConfig, QuantConfig
    return IndexConfig(
        dim=d["dim"], metric=d["metric"],
        build=BuildConfig(**d["build"]),
        search=SearchConfig(**d["search"]),
        quant=QuantConfig(**d["quant"]),
    )
