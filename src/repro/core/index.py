"""KBestIndex — the user-facing API (paper §4, Table 2).

    index = KBest(config)          # parameter preparation
    index.add(x)                   # index construction (build pipeline)
    d, i = index.search(q, k)      # query processing
    index.save(path) / KBest.load(path)

One facade, two index families (config.index_type):

"graph" build pipeline (DESIGN.md §3): kNN graph (brute / NN-descent) ->
edge selection -> F rounds of 2-hop refinement (A1) -> reverse-edge fill ->
graph reordering (A2) -> optional PQ/SQ training+encoding (A4) -> medoid
entry point. Search runs the batched traversal of core.search with early
termination (A3); quantized searches re-rank the top candidates with exact
distances (standard ADC + re-rank).

"ivf" build pipeline (DESIGN.md §4): k-means coarse quantizer -> residual
PQ training+encoding (A4, shared codebook knobs) -> padded dense inverted
lists. Search probes the nprobe nearest clusters, runs the fused ADC scan
with per-list partial top-L (kernels/ivf_scan), then re-ranks exactly via
the same gather path as the graph index.

Either family scales past one device through the sharded composition
(core/sharded.py: ShardedKBest, DESIGN.md §12): IndexConfig.n_shards > 1
builds one single-shard KBest per contiguous row range and merges
shard-local results; plain KBest always owns the whole corpus.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from pathlib import Path
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import ivf as ivf_mod
from repro.core import persist
from repro.core import quantize as qz
from repro.core import reorder as reorder_mod
from repro.core import search as search_mod
from repro.core.distance import normalize, pairwise
from repro.core.refine import refine_graph
from repro.core.types import IndexConfig, SearchConfig


class KBest:
    def __init__(self, config: IndexConfig):
        self.config = config
        self.db: Optional[jnp.ndarray] = None        # (n, d) f32 (normalized if cosine)
        self.graph: Optional[jnp.ndarray] = None     # (n, M) i32
        self.entry: int = 0
        self.order: Optional[np.ndarray] = None      # new->old id map
        # quantization state
        self.pq: Optional[qz.PQState] = None
        self.pq_codes: Optional[jnp.ndarray] = None
        self.sq: Optional[qz.SQState] = None
        self.sq_codes: Optional[jnp.ndarray] = None
        self.bin: Optional[qz.BinState] = None
        self.bin_codes: Optional[jnp.ndarray] = None
        self.ivf: Optional[ivf_mod.IVFState] = None
        self._dist_fns = {}

    # ------------------------------------------------------------------ add
    def add(self, x: np.ndarray) -> "KBest":
        cfg = self.config
        assert cfg.n_shards == 1, \
            "config.n_shards > 1 is the sharded composition — build it " \
            "with repro.core.sharded.ShardedKBest, not KBest"
        b = cfg.build
        x = jnp.asarray(x, dtype=jnp.float32)
        assert x.ndim == 2 and x.shape[1] == cfg.dim, x.shape
        if cfg.metric == "cosine":
            x = normalize(x)
        metric = "ip" if cfg.metric == "cosine" else cfg.metric

        if cfg.index_type == "ivf":
            self.db = x
            self.ivf = ivf_mod.build_ivf(x, cfg.ivf, cfg.quant)
            return self

        knn_ids, knn_dists = build_mod.build_knn(
            x, b.knn_k, metric, builder=b.builder,
            rounds=b.nn_descent_rounds, sample=b.nn_descent_sample, seed=b.seed)

        entry = build_mod.medoid(x, metric)
        graph = refine_graph(
            x, knn_ids, knn_dists, M=b.M, rule=b.select_rule, metric=metric,
            alpha=b.alpha, ssg_angle_deg=b.ssg_angle_deg,
            iters=b.refine_iters, cand_cap=b.refine_cands,
            entry=entry, search_L=b.search_L, search_passes=b.search_passes)

        if b.reorder != "none":
            weights = np.asarray(_edge_weights(x, graph, metric))
            if b.reorder == "mst":
                order = reorder_mod.mst_reorder(np.asarray(graph), weights, entry)
            elif b.reorder == "cm":
                order = reorder_mod.cuthill_mckee(np.asarray(graph), entry)
            else:
                raise ValueError(b.reorder)
            db2, g2, new_of_old = reorder_mod.apply_order(
                order, np.asarray(x), np.asarray(graph))
            x, graph = jnp.asarray(db2), jnp.asarray(g2)
            entry = int(new_of_old[entry])
            self.order = order

        self.db, self.graph, self.entry = x, jnp.asarray(graph), entry
        self._train_quant(x)
        return self

    def _train_quant(self, x: jnp.ndarray) -> None:
        """Train + encode the configured quantizer over the stored db (also
        used to attach a different quantizer to an already-built graph,
        e.g. the quantization ablation)."""
        q = self.config.quant
        if q.kind == "pq":
            self.pq = qz.pq_train(x, q)
            self.pq_codes = qz.pq_encode(self.pq.codebooks, x)
        elif q.kind == "pq4":
            self.pq = qz.pq_train(x, q)                 # (m, 16, ds) books
            self.pq_codes = qz.pq4_encode(self.pq.codebooks, x)  # packed
        elif q.kind == "sq":
            self.sq = qz.sq_train(x)
            self.sq_codes = qz.sq_encode(self.sq, x)
        elif q.kind == "bin":
            self.bin = qz.bin_train(x, q)
            self.bin_codes = qz.bin_encode(self.bin, x)   # (n, ceil(d/32)) u32

    # --------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: Optional[int] = None,
               search_cfg: Optional[SearchConfig] = None,
               with_stats: bool = False):
        """Top-k search. queries: (Q, d). Returns (dists, ids[, stats])."""
        assert self.db is not None, "call add() first"
        scfg = self._resolve_cfg(k, search_cfg)
        dists, ids, stats = self._search_impl(
            self._prep_queries(queries), scfg, valid_mask=None,
            with_stats=with_stats)
        if with_stats:
            return dists, ids, stats
        return dists, ids

    def search_padded(self, queries: np.ndarray, valid_mask: np.ndarray,
                      k: Optional[int] = None,
                      search_cfg: Optional[SearchConfig] = None,
                      with_stats: bool = False):
        """Shape-stable search over a padded batch (the serving entry point).

        queries: (B, d) where only rows with valid_mask[i] are real requests;
        padded rows come back as (+inf, -1) with zeroed stats, and valid
        rows are bit-identical to an unpadded `search` of the same queries,
        so a serving engine can pad every incoming batch to a fixed set of
        shape buckets and never re-trace. For the graph index padded rows
        start inactive in the lockstep traversal (free idle lanes,
        core.search's masking); the IVF scan is dense per-lane work with no
        loop to idle, so its padded lanes still compute (then get masked) —
        bucketing amortizes that to at most one bucket step of slack.
        """
        assert self.db is not None, "call add() first"
        scfg = self._resolve_cfg(k, search_cfg)
        vm = jnp.asarray(valid_mask, dtype=bool)
        dists, ids, stats = self._search_impl(
            self._prep_queries(queries), scfg, valid_mask=vm,
            with_stats=with_stats)
        dists, ids, stats = mask_padded_lanes(vm, dists, ids, stats)
        if with_stats:
            return dists, ids, stats
        return dists, ids

    def _resolve_cfg(self, k: Optional[int],
                     search_cfg: Optional[SearchConfig]) -> SearchConfig:
        return resolve_search_cfg(self.config, k, search_cfg)

    def _prep_queries(self, queries) -> jnp.ndarray:
        return prep_queries(self.config, queries)

    def _search_impl(self, q: jnp.ndarray, scfg: SearchConfig,
                     valid_mask: Optional[jnp.ndarray],
                     with_stats: bool):
        """Shared body of search/search_padded. Pure jax ops on concrete
        configs, so the serving engine can close over it under one jit trace
        per (shape bucket, config) key. Returns (dists, ids, stats|None)."""
        cfg = self.config
        metric = "ip" if cfg.metric == "cosine" else cfg.metric
        n = self.db.shape[0]

        if cfg.index_type == "ivf":
            Q = q.shape[0]
            wide = _widen_bin(scfg) if cfg.quant.kind == "bin" else _widen(scfg)
            _, cand, probes = ivf_mod.search_ivf(
                self.ivf, q, scfg.nprobe, wide.L, metric,
                impl=scfg.dist_impl,
                lut_u8=cfg.quant.kind == "pq4" and cfg.quant.pq4_lut_u8)
            # default: re-rank the WHOLE candidate queue — the ADC scan is
            # far cheaper per candidate than graph traversal, so the exact
            # pass (L distances/query) is where IVF recall is won back.
            # bin instead reranks its explicit rescore_factor*k overfetch
            # (DESIGN.md §14), so recall is monotone in the factor.
            if cfg.quant.kind == "bin" and cfg.quant.rerank == 0:
                rr = scfg.rescore_factor * scfg.k
            else:
                rr = cfg.quant.rerank if cfg.quant.rerank > 0 else cand.shape[1]
            dists, ids, n_exact = self._rerank(q, cand, metric, scfg.k,
                                               rr, impl=scfg.dist_impl)
            if not with_stats:
                return dists, ids, None
            # scanned PQ codes + the exact re-rank distances, so the
            # benchmark's dists_per_query column is comparable across
            # index families
            stats = search_mod.SearchStats(
                n_hops=jnp.full((Q,), min(scfg.nprobe, self.ivf.nlist),
                                jnp.int32),
                n_dist=ivf_mod.scanned_counts(self.ivf, probes) + n_exact,
                early_terminated=jnp.zeros((Q,), bool),
                iters=jnp.int32(0))
            return dists, ids, stats

        entry_ids = self._entry_ids(scfg.n_entries, n)
        quant = cfg.quant.kind

        if quant in ("pq", "pq4"):
            if quant == "pq":
                tables = qz.pq_query_tables(self.pq.codebooks, q, metric)
            else:
                tables = qz.pq4_query_tables(self.pq.codebooks, q, metric,
                                             lut_u8=cfg.quant.pq4_lut_u8)
            wide = _widen(scfg)
            dist_fn = self._get_dist_fn(quant, scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, tables, entry_ids, dist_fn=dist_fn, cfg=wide,
                n_total=n, valid_mask=valid_mask,
                expand_fn=self._get_expand_fn(quant, wide))
            dists, ids, n_exact = self._rerank(q, ids, metric, scfg.k,
                                               cfg.quant.rerank,
                                               impl=scfg.dist_impl)
        elif quant == "sq":
            wide = _widen(scfg)
            dist_fn = self._get_dist_fn("sq", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, q, entry_ids, dist_fn=dist_fn, cfg=wide,
                n_total=n, valid_mask=valid_mask,
                expand_fn=self._get_expand_fn("sq", wide))
            dists, ids, n_exact = self._rerank(q, ids, metric, scfg.k,
                                               cfg.quant.rerank,
                                               impl=scfg.dist_impl)
        elif quant == "bin":
            # two-stage rescore (DESIGN.md §14): traverse under packed
            # Hamming with the queue widened to hold rescore_factor*k
            # candidates, then exact re-rank that overfetch
            qcodes = qz.bin_query_codes(self.bin, q)
            wide = _widen_bin(scfg)
            dist_fn = self._get_dist_fn("bin", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, qcodes, entry_ids, dist_fn=dist_fn, cfg=wide,
                n_total=n, valid_mask=valid_mask,
                expand_fn=self._get_expand_fn("bin", wide))
            rr = cfg.quant.rerank if cfg.quant.rerank > 0 \
                else scfg.rescore_factor * scfg.k
            dists, ids, n_exact = self._rerank(q, ids, metric, scfg.k, rr,
                                               impl=scfg.dist_impl)
        else:
            n_exact = None
            dist_fn = self._get_dist_fn("full", scfg.dist_impl)
            dists, ids, stats = search_mod.search(
                self.graph, q, entry_ids, dist_fn=dist_fn, cfg=scfg,
                n_total=n, valid_mask=valid_mask,
                expand_fn=self._get_expand_fn("full", scfg))

        if n_exact is not None:
            # the quantized first pass counts ADC lookups in n_dist; the
            # exact re-rank distances must be counted too, or the graph-PQ/SQ
            # rows undercount vs. the IVF path (which adds its re-rank) and
            # the cross-family dists_per_query comparison silently breaks
            stats = stats._replace(n_dist=stats.n_dist + n_exact)

        # translate internal (post-reorder) ids back to the user's add() ids
        if self.order is not None:
            order = jnp.asarray(self.order, dtype=jnp.int32)
            ids = jnp.where(ids >= 0, order[jnp.maximum(ids, 0)], -1)

        return dists, ids, (stats if with_stats else None)

    def _entry_ids(self, n_entries: int, n: int) -> jnp.ndarray:
        """Medoid + evenly-spaced deterministic seeds: cheap cluster coverage
        for the lockstep search (the paper uses a random-or-fixed entry;
        multiple entries are the batched equivalent of per-thread random
        entries).

        The offsets are strictly increasing integers in [1, n-1] (linspace
        step >= 1 because e <= n), so all e ids are DISTINCT — the old
        strided form `entry + i*(n//e) mod n` could wrap duplicates onto the
        medoid for small n, which both wastes queue slots and hands
        duplicate ids to the bitmap seeding (see _bitmap_set's disjointness
        contract)."""
        e = max(1, min(n_entries, n))
        if e == 1:
            return jnp.array([self.entry % n], jnp.int32)
        off = np.round(np.linspace(1, n - 1, e - 1)).astype(np.int64)
        ids = (self.entry + np.concatenate([[0], off])) % n
        return jnp.asarray(ids, jnp.int32)

    def _get_dist_fn(self, kind: str, impl: str):
        key = (kind, impl)
        if key not in self._dist_fns:
            metric = "ip" if self.config.metric == "cosine" else self.config.metric
            if kind == "full":
                fn = search_mod.make_dist_fn(self.db, metric, impl)
            elif kind == "pq":
                fn = qz.pq_make_dist_fn(self.pq_codes, self.pq.m, impl)
            elif kind == "pq4":
                fn = qz.pq4_make_dist_fn(self.pq_codes, self.pq.m, impl)
            elif kind == "sq":
                fn = qz.sq_make_dist_fn(self.sq_codes, self.sq, metric, impl)
            elif kind == "bin":
                fn = qz.bin_make_dist_fn(self.bin_codes, impl)
            else:
                raise ValueError(kind)
            self._dist_fns[key] = fn
        return self._dist_fns[key]

    def _get_expand_fn(self, kind: str, scfg: SearchConfig):
        """Fused gather+distance+sort backend for the beam traversal
        (kernels/traverse_step.py), or None for the dist_fn + host-sort
        path. Engaged only for kernel-impl beam searches: W=1 keeps the
        seed gather-then-merge kernel path (the bit-parity anchor), and a
        set batch_B means chunked dist_fn calls (core.search honors the
        knob by falling back). Cached per (kind, L, W) — the closures are
        jit static args, so their identity must be stable across calls."""
        if scfg.dist_impl != "kernel" or scfg.beam_width <= 1 \
                or scfg.batch_B != 0:
            return None
        L, W = scfg.L, scfg.beam_width
        key = (kind, "expand", L, W)
        if key not in self._dist_fns:
            from repro.kernels import ops as kops
            metric = "ip" if self.config.metric == "cosine" else self.config.metric
            if kind == "full":
                fn = search_mod.make_expand_fn(self.db, metric, L=L, n_beam=W)
            elif kind in ("pq", "pq4"):
                m = self.pq.m
                K = 16 if kind == "pq4" else 256
                codes = self.pq_codes
                fe = kops.fused_expand_pq4 if kind == "pq4" else kops.fused_expand_pq

                def fn(tables, nbr_ids, _fe=fe, _m=m, _K=K, _codes=codes):
                    lut = tables.reshape(tables.shape[0], _m, _K)
                    return _fe(lut, _codes, nbr_ids, L=L, n_beam=W)
            elif kind == "sq":
                codes, sq = self.sq_codes, self.sq

                def fn(queries, nbr_ids, _codes=codes, _sq=sq):
                    return kops.fused_expand_sq(
                        queries, _codes, _sq.scale.reshape(1, -1),
                        _sq.zero.reshape(1, -1), nbr_ids,
                        metric=metric, L=L, n_beam=W)
            elif kind == "bin":
                codes = self.bin_codes

                def fn(qcodes, nbr_ids, _codes=codes):
                    return kops.fused_expand_bin(qcodes, _codes, nbr_ids,
                                                 L=L, n_beam=W)
            else:
                raise ValueError(kind)
            self._dist_fns[key] = fn
        return self._dist_fns[key]

    def _rerank(self, q, ids, metric, k, rerank, impl: str = "ref"):
        """Exact re-rank of the quantized/IVF search's top candidates, via
        the gather-then-distance path (Pallas gather_dist when impl is
        "kernel", jnp gather otherwise). Returns (dists (Q, k), ids (Q, k),
        n_exact (Q,) i32 — the exact distances actually computed, for the
        cross-family n_dist accounting)."""
        r = rerank if rerank > 0 else min(4 * k, ids.shape[1])
        r = min(max(r, k), ids.shape[1])   # never fewer candidates than k
        cand = ids[:, :r]
        if impl == "kernel":
            from repro.kernels import ops as kops
            d = kops.gather_dist(q, self.db, cand, metric=metric)
        else:
            vecs = self.db[jnp.maximum(cand, 0)]
            from repro.core.distance import batched_one_to_many
            d = batched_one_to_many(q, vecs, metric)
        d = jnp.where(cand >= 0, d, jnp.inf)
        neg, pos = jax.lax.top_k(-d, k)
        n_exact = jnp.sum(cand >= 0, axis=1).astype(jnp.int32)
        return -neg, jnp.take_along_axis(cand, pos, axis=1), n_exact

    # ------------------------------------------------------------ save/load
    def save(self, path: str, _label: str = "index") -> None:
        """Crash-safe save (DESIGN.md §17): the .npz is written atomically
        (tmp + fsync + rename), then the JSON sidecar — carrying a crc32
        per array — commits the save atomically after it. A crash at any
        point leaves either the previous save or a pair load() rejects;
        `_label` namespaces the kill points (sharded saves pass shard{s})."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        arrs = {"db": np.asarray(self.db)}
        if self.graph is not None:
            arrs["graph"] = np.asarray(self.graph)
        if self.ivf is not None:
            arrs["ivf_centroids"] = np.asarray(self.ivf.centroids)
            arrs["ivf_list_ids"] = np.asarray(self.ivf.list_ids)
            arrs["ivf_list_codes"] = np.asarray(self.ivf.list_codes)
            if self.ivf.pq is not None:
                arrs["ivf_codebooks"] = np.asarray(self.ivf.pq.codebooks)
            if self.ivf.bin is not None:
                arrs["ivf_bin_rot"] = np.asarray(self.ivf.bin.rot)
        if self.order is not None:
            arrs["order"] = np.asarray(self.order)
        if self.pq is not None:
            arrs["pq_codebooks"] = np.asarray(self.pq.codebooks)
            arrs["pq_codes"] = np.asarray(self.pq_codes)
        if self.sq is not None:
            arrs["sq_scale"] = np.asarray(self.sq.scale)
            arrs["sq_zero"] = np.asarray(self.sq.zero)
            arrs["sq_codes"] = np.asarray(self.sq_codes)
        if self.bin is not None:
            arrs["bin_rot"] = np.asarray(self.bin.rot)
            arrs["bin_codes"] = np.asarray(self.bin_codes)
        sums = persist.save_arrays(_npz_path(p), arrs, f"{_label}.arrays")
        meta = {"entry": self.entry,
                "config": _config_to_dict(self.config),
                "format": 2,
                "checksums": sums}
        # append ".json" to the FULL name: with_suffix(".json") used to map
        # both save("a.graph") and save("a.ivf") onto "a.json", so two
        # indexes sharing a stem clobbered each other's metadata
        persist.atomic_write(_meta_path(p), json.dumps(meta).encode(),
                             f"{_label}.meta")

    @classmethod
    def load(cls, path: str) -> "KBest":
        """Load with validation (DESIGN.md §17): any unreadable/torn sidecar
        or npz, and any array whose crc32 disagrees with the sidecar's,
        raises persist.IndexCorruptError — never a silently wrong index.
        Sidecars from pre-checksum saves (no "checksums" key) still load."""
        p = Path(path)
        mp = _meta_path(p)
        if not mp.exists() and p.with_suffix(".json").exists():
            mp = p.with_suffix(".json")     # pre-fix saves (load-compat)
        try:
            meta = json.loads(mp.read_text())
        except FileNotFoundError:
            raise
        except Exception as e:              # torn/garbage sidecar bytes
            raise persist.IndexCorruptError(
                f"unreadable index sidecar at {mp}: {e!r}") from e
        cfg = _config_from_dict(meta["config"])
        idx = cls(cfg)
        z = persist.load_arrays(_npz_path(p), meta.get("checksums"))
        idx.db = jnp.asarray(z["db"])
        if "graph" in z:
            idx.graph = jnp.asarray(z["graph"])
        if "ivf_centroids" in z:
            pq_state = None
            if "ivf_codebooks" in z:
                books = jnp.asarray(z["ivf_codebooks"])
                pq_state = qz.PQState(books, books.shape[0],
                                      books.shape[2])
            bin_state = qz.BinState(jnp.asarray(z["ivf_bin_rot"])) \
                if "ivf_bin_rot" in z else None
            idx.ivf = ivf_mod.IVFState(
                centroids=jnp.asarray(z["ivf_centroids"]),
                list_ids=jnp.asarray(z["ivf_list_ids"]),
                list_codes=jnp.asarray(z["ivf_list_codes"]),
                pq=pq_state,
                residual=cfg.ivf.residual,
                packed=cfg.quant.kind == "pq4",
                bin=bin_state)
        if "pq_codebooks" in z:
            books = jnp.asarray(z["pq_codebooks"])
            idx.pq = qz.PQState(books, books.shape[0], books.shape[2])
            idx.pq_codes = jnp.asarray(z["pq_codes"])
        if "sq_scale" in z:
            idx.sq = qz.SQState(jnp.asarray(z["sq_scale"]),
                                jnp.asarray(z["sq_zero"]))
            idx.sq_codes = jnp.asarray(z["sq_codes"])
        if "bin_rot" in z:
            idx.bin = qz.BinState(jnp.asarray(z["bin_rot"]))
            idx.bin_codes = jnp.asarray(z["bin_codes"])
        if "order" in z:
            idx.order = np.asarray(z["order"])
        idx.entry = int(meta["entry"])
        return idx


def resolve_search_cfg(config: IndexConfig, k: Optional[int],
                       search_cfg: Optional[SearchConfig]) -> SearchConfig:
    """Fold a per-call k override into a concrete SearchConfig (shared by
    KBest, ShardedKBest and the serving engine's cache keying)."""
    scfg = search_cfg or config.search
    if k is not None and k != scfg.k:
        # k > L would trip SearchConfig's k <= L invariant; a caller
        # asking for more results than the queue holds means "widen the
        # queue to fit", not "crash".
        scfg = dataclasses.replace(scfg, k=k, L=max(scfg.L, k))
    return scfg


def prep_queries(config: IndexConfig, queries) -> jnp.ndarray:
    """Query-side add()-time preprocessing: f32 cast + cosine normalize."""
    q = jnp.asarray(queries, dtype=jnp.float32)
    if config.metric == "cosine":
        q = normalize(q)
    return q


def mask_padded_lanes(vm: jnp.ndarray, dists: jnp.ndarray, ids: jnp.ndarray,
                      stats):
    """The search_padded output contract, in one place for every facade
    (KBest and ShardedKBest must stay bit-compatible for the serving
    engine): invalid lanes come back as (+inf, -1) with zeroed stats.
    `stats` may be None (with_stats=False) and passes through."""
    dists = jnp.where(vm[:, None], dists, jnp.inf)
    ids = jnp.where(vm[:, None], ids, -1)
    if stats is not None:
        stats = search_mod.SearchStats(
            n_hops=jnp.where(vm, stats.n_hops, 0),
            n_dist=jnp.where(vm, stats.n_dist, 0),
            early_terminated=stats.early_terminated & vm,
            iters=stats.iters)
    return dists, ids, stats


def _widen(scfg: SearchConfig) -> SearchConfig:
    """Quantized first-pass searches return their whole (wide) queue so the
    exact re-rank has at least 4k candidates to work with."""
    want = max(scfg.L, 4 * scfg.k)
    return dataclasses.replace(scfg, L=want, k=want)


def _widen_bin(scfg: SearchConfig) -> SearchConfig:
    """bin first pass (DESIGN.md §14): the Hamming queue must hold the
    rescore_factor*k overfetch the exact rescore picks from. L stays at
    max(L, rescore_factor*k): while rescore_factor*k <= L the traversal is
    IDENTICAL across factors and a deeper factor just rescores a longer
    prefix of the same Hamming ranking, so recall is deterministically
    non-decreasing in rescore_factor; past L/k the queue itself widens."""
    want = max(scfg.L, scfg.rescore_factor * scfg.k)
    return dataclasses.replace(scfg, L=want, k=want)


def _edge_weights(db: jnp.ndarray, graph: jnp.ndarray, metric: str) -> jnp.ndarray:
    from repro.core.refine import _chunk_dists
    n = graph.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)
    out = []
    for s in range(0, n, 1024):
        e = min(s + 1024, n)
        out.append(_chunk_dists(db, rows[s:e], graph[s:e], metric))
    w = jnp.concatenate(out, axis=0)
    return jnp.where(jnp.isfinite(w), w, 0.0)


def _meta_path(p: Path) -> Path:
    """Metadata sidecar: the FULL array-file name + ".json" (so "a.graph"
    and "a.ivf" get distinct sidecars, unlike with_suffix)."""
    return p.with_name(p.name + ".json")


def _npz_path(p: Path) -> Path:
    """The array file np.savez would have produced for `p` (".npz" appended
    unless already present) — save and load must agree on it."""
    return p if p.suffix == ".npz" else Path(str(p) + ".npz")


def _known_fields(cls, d: dict) -> dict:
    """Drop keys a (possibly older) checkout's dataclass doesn't know, so
    metadata written by newer versions (e.g. pq4-era QuantConfig fields)
    still loads instead of raising TypeError. The drop is warned about,
    not silent: a forward-compat load that loses knobs (and their tuned
    values) should be observable in logs."""
    names = {f.name for f in dataclasses.fields(cls)}
    dropped = sorted(set(d) - names)
    if dropped:
        warnings.warn(
            f"index metadata has {cls.__name__} keys {dropped} unknown to "
            f"this version — loading without them (their saved values are "
            f"discarded)", stacklevel=2)
    return {k: v for k, v in d.items() if k in names}


def _config_to_dict(cfg: IndexConfig) -> dict:
    return dataclasses.asdict(cfg)


def _config_from_dict(d: dict) -> IndexConfig:
    from repro.core.types import BuildConfig, IVFConfig, QuantConfig
    return IndexConfig(
        dim=d["dim"], metric=d["metric"],
        index_type=d.get("index_type", "graph"),
        n_shards=d.get("n_shards", 1),
        build=BuildConfig(**_known_fields(BuildConfig, d["build"])),
        search=SearchConfig(**_known_fields(SearchConfig, d["search"])),
        quant=QuantConfig(**_known_fields(QuantConfig, d["quant"])),
        ivf=IVFConfig(**_known_fields(IVFConfig, d.get("ivf", {}))),
    )
