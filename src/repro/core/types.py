"""Shared dataclasses for the KBest core library.

All configs are plain frozen dataclasses so they hash (usable as jit static
args) and serialize trivially into checkpoint metadata.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Distance metrics. "l2" is squared Euclidean (monotone in true L2, the
# standard ANNS convention), "ip" is negative inner product, "cosine" is
# negative cosine similarity (vectors are L2-normalized at add() time and the
# metric degenerates to "ip").
METRICS = ("l2", "ip", "cosine")

# Edge-selection rules supported by the refinement pipeline (paper §3.2).
SELECT_RULES = ("none", "hnsw", "alpha", "ssg")

# Index families behind the KBest facade (DESIGN.md §3 graph, §4 ivf).
INDEX_TYPES = ("graph", "ivf")

# Quantization kinds accepted by QuantConfig — THE single registry
# (DESIGN.md §13/§14). Sweeps (core/tune.py, benchmarks/ablation.py)
# enumerate quantize.quant_variants(), which is asserted against this
# tuple in tests, so a new kind lands in every sweep automatically.
QUANT_KINDS = ("none", "pq", "pq4", "sq", "bin")


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Index-construction parameters (paper: Add / build phase)."""

    M: int = 32                  # fixed out-degree of the CSR graph
    knn_k: int = 48              # size of the initial kNN neighborhood
    builder: str = "auto"        # "brute" | "nn_descent" | "auto"
    nn_descent_rounds: int = 6   # NN-descent iterations
    nn_descent_sample: int = 12  # neighbors-of-neighbors sampled per round
    select_rule: str = "alpha"   # edge selection rule (SELECT_RULES)
    alpha: float = 1.2           # Vamana/NSG pruning slack
    ssg_angle_deg: float = 60.0  # SSG minimum pairwise edge angle
    refine_iters: int = 1        # F: 2-hop iterative refinement rounds (A1)
    refine_cands: int = 96       # candidate pool cap per node during refine
    search_passes: int = 1       # search-based refinement passes (A1 phase 2)
    search_L: int = 48           # queue size of the build-time searches
    reorder: str = "mst"         # "none" | "mst" (Algorithm 2) | "cm"
    seed: int = 0

    def __post_init__(self):
        assert self.select_rule in SELECT_RULES, self.select_rule
        assert self.builder in ("brute", "nn_descent", "auto"), self.builder
        assert self.M >= 2 and self.knn_k >= self.M


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Query-time parameters (paper: Search phase, Algorithm 1 + Eq. 3)."""

    L: int = 64                  # candidate queue size (a.k.a. efSearch)
    k: int = 10                  # results returned
    max_hops: int = 0            # 0 => derived (4*L) safety bound
    # --- early termination (Eq. 3) ---
    early_term: bool = True
    et_t_frac: float = 0.6       # threshold position t as a fraction of L
    et_patience: int = 16        # tau_max: consecutive beyond-t insertions
    # --- batched traversal ---
    visited_mode: str = "queue"  # "queue" (in-queue dedupe) | "bitmap" (exact)
    dist_impl: str = "ref"       # "ref" | "kernel" — distance backend
    beam_width: int = 1          # W: expansions per lockstep iteration (§2)
    batch_B: int = 0             # distance-batch chunk: the W*M candidate
                                 # axis is split into batch_B-sized dist
                                 # calls; 0 => one (Q, W*M) call (see §2)
    n_entries: int = 8           # entry points: medoid + (n-1) strided seeds
    # --- two-stage rescore (kind="bin" only, DESIGN.md §14) ---
    rescore_factor: int = 8      # overfetch rescore_factor*k Hamming
                                 # candidates, then exact re-rank; other
                                 # quant kinds use QuantConfig.rerank
    # --- IVF-only (ignored by the graph index, DESIGN.md §4) ---
    nprobe: int = 8              # probed clusters per query

    def __post_init__(self):
        assert self.k <= self.L, (self.k, self.L)
        assert self.visited_mode in ("queue", "bitmap")
        assert 0.0 < self.et_t_frac <= 1.0
        assert self.nprobe >= 1
        assert self.rescore_factor >= 1, self.rescore_factor
        # the beam picks W unvisited queue slots per step — more than L
        # slots can never exist, so a wider beam is a config error
        assert 1 <= self.beam_width <= self.L, (self.beam_width, self.L)
        assert self.batch_B >= 0, self.batch_B

    @property
    def hops_bound(self) -> int:
        return self.max_hops if self.max_hops > 0 else 4 * self.L


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Vector quantization (paper §3.2, A4).

    kind (QUANT_KINDS): "none" | "pq" (8-bit, 256-centroid sub-codebooks)
    | "pq4" (4-bit fast-scan: 16-centroid sub-codebooks, two codes packed
    per byte, LUT small enough to stay VMEM/register resident — DESIGN.md
    §13) | "sq" (int8 per-dimension affine) | "bin" (1-bit random-rotation
    sign codec, u32-packed, XOR+popcount Hamming first pass + exact
    rescore — DESIGN.md §14; overfetch via SearchConfig.rescore_factor).
    """

    kind: str = "none"
    pq_m: int = 8                # number of PQ subspaces
    pq_bits: int = 8             # bits per code for kind="pq" (256 centroids)
    pq4_lut_u8: bool = False     # fast-scan style per-query u8 LUT requant
    kmeans_iters: int = 10
    rerank: int = 0              # exact re-rank depth (0 => 4*k at search)
    seed: int = 0

    def __post_init__(self):
        assert self.kind in QUANT_KINDS, self.kind
        if self.kind == "pq4":
            # nbits is authoritative (4); tolerate an explicit pq_bits=4 or
            # the untouched default 8 rather than crash on the natural call
            # QuantConfig(kind="pq4", pq_bits=4)
            assert self.pq_bits in (4, 8), \
                f"pq4 codes are 4-bit (pq_bits ignored), got {self.pq_bits}"
            assert self.pq_m % 2 == 0, \
                f"pq4 packs two codes per byte: pq_m must be even, got {self.pq_m}"
        else:
            assert self.pq_bits == 8, "kind='pq' is 8-bit; use kind='pq4' for 4"

    @property
    def nbits(self) -> int:
        """Bits per PQ code (4 for the fast-scan family, else pq_bits)."""
        return 4 if self.kind == "pq4" else self.pq_bits

    @property
    def ksub(self) -> int:
        """Centroids per sub-codebook (16 for pq4, 256 for pq)."""
        return 1 << self.nbits


@dataclasses.dataclass(frozen=True)
class IVFConfig:
    """IVF coarse-partitioning parameters (DESIGN.md §4).

    The fine (PQ) stage reuses QuantConfig (pq_m / kmeans_iters / rerank) so
    the same codebook knobs drive both graph-PQ and IVF-PQ.
    """

    nlist: int = 0               # coarse clusters; 0 => round(sqrt(n))
    kmeans_iters: int = 10       # Lloyd iterations of the coarse quantizer
    residual: bool = True        # encode x - centroid (True) or raw x
    list_pad: int = 128          # pad inverted-list length to this multiple
                                 # (lane width: the H3 alignment analogue)
    seed: int = 0

    def __post_init__(self):
        assert self.nlist >= 0 and self.list_pad >= 1


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Top-level config handed to KBest(config) (paper Table 2).

    n_shards > 1 selects the sharded composition (core/sharded.py:
    ShardedKBest — DESIGN.md §12): the corpus is split into n_shards
    contiguous row ranges, each built as an independent single-shard index
    of this same config, searched shard-locally and merged. Plain KBest
    requires n_shards == 1.
    """

    dim: int
    metric: str = "l2"
    index_type: str = "graph"    # INDEX_TYPES: "graph" | "ivf"
    n_shards: int = 1            # flat mesh shape of the sharded composition
    build: BuildConfig = dataclasses.field(default_factory=BuildConfig)
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    ivf: IVFConfig = dataclasses.field(default_factory=IVFConfig)

    def __post_init__(self):
        assert self.metric in METRICS, self.metric
        assert self.index_type in INDEX_TYPES, self.index_type
        assert self.n_shards >= 1, self.n_shards
