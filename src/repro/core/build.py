"""kNN-graph construction (paper §3.2, phase 1 of index refinement).

Two builders:
  * brute_force_knn — tiled exact kNN; the (chunk, n) distance tiles are the
    Q-to-B batched-distance workload that the batch_dist Pallas kernel
    implements on the MXU (DESIGN.md H1).
  * nn_descent — jit-friendly fixed-round NN-descent (paper uses RNNDescent;
    same family: iterate "my neighbors' neighbors are candidates").
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.distance import pairwise


def _merge_topk(ids_a, dists_a, ids_b, dists_b, k) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise merge of two candidate sets with id-dedupe, keep k best."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    dists = jnp.concatenate([dists_a, dists_b], axis=-1)
    # sort by id, kill duplicates (neighboring equal ids), re-sort by dist
    order = jnp.argsort(ids, axis=-1, stable=True)
    ids_s = jnp.take_along_axis(ids, order, axis=-1)
    dists_s = jnp.take_along_axis(dists, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[..., :1], dtype=bool), ids_s[..., 1:] == ids_s[..., :-1]],
        axis=-1)
    dists_s = jnp.where(dup | (ids_s < 0), jnp.inf, dists_s)
    order2 = jnp.argsort(dists_s, axis=-1, stable=True)[..., :k]
    return (jnp.take_along_axis(ids_s, order2, axis=-1),
            jnp.take_along_axis(dists_s, order2, axis=-1))


@functools.partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def brute_force_knn(db: jnp.ndarray, k: int, metric: str, chunk: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact kNN. Returns (ids (n, k), dists (n, k)), self excluded."""
    n, d = db.shape
    n_chunks = -(-n // chunk)
    n_pad = n_chunks * chunk
    dbp = jnp.pad(db, ((0, n_pad - n), (0, 0)))

    def body(i):
        qs = jax.lax.dynamic_slice(dbp, (i * chunk, 0), (chunk, d))
        dm = pairwise(qs, db, metric)                       # (chunk, n)
        rows = i * chunk + jnp.arange(chunk)
        dm = jnp.where(jnp.arange(n)[None, :] == rows[:, None], jnp.inf, dm)
        neg, idx = jax.lax.top_k(-dm, k)
        return idx.astype(jnp.int32), -neg

    ids, dists = jax.lax.map(body, jnp.arange(n_chunks))
    return ids.reshape(n_pad, k)[:n], dists.reshape(n_pad, k)[:n]


def _gather_dists(db: jnp.ndarray, ids: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Per-row distances d(db[i], db[ids[i, j]]) with -1 masked to inf."""
    vecs = db[jnp.maximum(ids, 0)]                          # (n, C, d)
    if metric == "l2":
        diff = vecs - db[:, None, :]
        out = jnp.sum(diff * diff, axis=-1)
    else:
        out = -jnp.einsum("ncd,nd->nc", vecs, db)
    return jnp.where(ids >= 0, out, jnp.inf)


@functools.partial(jax.jit, static_argnames=("k", "metric", "rounds", "sample"))
def nn_descent(db: jnp.ndarray, k: int, metric: str, rounds: int = 6,
               sample: int = 12, seed: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate kNN graph by fixed-round NN-descent.

    Candidates per round: current neighbors ∪ (first `sample` neighbors of
    each neighbor). Distances are the (n, C, d) batched gather-einsum — the
    same Q-to-B workload as search, so the MXU path applies at build time.
    """
    n, d = db.shape
    key = jax.random.PRNGKey(seed)
    ids = jax.random.randint(key, (n, k), 0, n, dtype=jnp.int32)
    # avoid trivial self edges
    ids = jnp.where(ids == jnp.arange(n, dtype=jnp.int32)[:, None], (ids + 1) % n, ids)
    dists = _gather_dists(db, ids, metric)
    ids, dists = _merge_topk(ids, dists, ids, dists, k)  # dedupe the random init

    def round_fn(carry, _):
        ids, dists = carry
        nbr2 = ids[jnp.maximum(ids, 0)][:, :, :sample].reshape(n, -1)   # (n, k*sample)
        nbr2 = jnp.where(nbr2 == jnp.arange(n, dtype=jnp.int32)[:, None], -1, nbr2)
        d2 = _gather_dists(db, nbr2, metric)
        ids, dists = _merge_topk(ids, dists, nbr2, d2, k)
        return (ids, dists), None

    (ids, dists), _ = jax.lax.scan(round_fn, (ids, dists), None, length=rounds)
    return ids, dists


def build_knn(db: jnp.ndarray, k: int, metric: str, builder: str = "auto",
              rounds: int = 6, sample: int = 12, seed: int = 0,
              brute_threshold: int = 20_000) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = db.shape[0]
    if builder == "auto":
        builder = "brute" if n <= brute_threshold else "nn_descent"
    if builder == "brute":
        return brute_force_knn(db, k, metric)
    return nn_descent(db, k, metric, rounds=rounds, sample=sample, seed=seed)


def medoid(db: jnp.ndarray, metric: str = "l2", sample: int = 4096, seed: int = 0) -> int:
    """Entry point: the vector closest to the dataset mean (cheap medoid)."""
    mean = jnp.mean(db, axis=0, keepdims=True)
    d = pairwise(mean, db, "l2")[0]
    return int(jnp.argmin(d))
