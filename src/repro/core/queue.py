"""Fixed-size sorted candidate queue (the priority queue of Algorithm 1).

JAX needs static shapes, so the queue is a struct-of-arrays of length L kept
sorted ascending by distance:

  dists   f32 (L,)  +inf in empty slots
  ids     i32 (L,)  -1   in empty slots
  visited bool (L,) True in empty slots (so they are never expanded)

`merge_insert` is the single batched operation the traversal needs: merge M
candidate (dist, id) pairs into the queue, deduplicating against the queue
and within the batch, and report the insertion rank of the best surviving
new candidate — which is exactly the signal Eq. 3 (early termination) needs.

Everything is written for a single query and lifted with jax.vmap by the
search loop.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


class Queue(NamedTuple):
    dists: jnp.ndarray    # (L,) f32, ascending
    ids: jnp.ndarray      # (L,) i32
    visited: jnp.ndarray  # (L,) bool


def init_queue(L: int) -> Queue:
    return Queue(
        dists=jnp.full((L,), INF, dtype=jnp.float32),
        ids=jnp.full((L,), -1, dtype=jnp.int32),
        visited=jnp.ones((L,), dtype=bool),
    )


def _dedupe_new(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Invalidate new entries that duplicate the queue or earlier new entries."""
    in_queue = jnp.any(new_ids[:, None] == q.ids[None, :], axis=1)
    # duplicate of an earlier element within the batch (strict lower triangle)
    m = new_ids.shape[0]
    dup_prior = jnp.any(
        (new_ids[:, None] == new_ids[None, :]) & (jnp.arange(m)[None, :] < jnp.arange(m)[:, None]),
        axis=1,
    )
    bad = in_queue | dup_prior | (new_ids < 0)
    return jnp.where(bad, INF, new_dists), jnp.where(bad, -1, new_ids)


def merge_insert(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray
                 ) -> Tuple[Queue, jnp.ndarray, jnp.ndarray]:
    """Merge (new_dists, new_ids) into the queue.

    Returns (queue', best_rank, n_inserted) where best_rank is the rank (0-
    based position in the merged order) of the best *new* candidate, or L if
    nothing was inserted — the Eq. 3 insertion position p for this step.
    """
    L = q.dists.shape[0]
    nd, ni = _dedupe_new(q, new_dists, new_ids)

    cat_d = jnp.concatenate([q.dists, nd])
    cat_i = jnp.concatenate([q.ids, ni])
    cat_v = jnp.concatenate([q.visited, jnp.zeros_like(ni, dtype=bool)])

    # Stable ascending sort by distance; ties keep existing entries first so
    # visited flags are preserved across no-op merges.
    order = jnp.argsort(cat_d, stable=True)
    sd, si, sv = cat_d[order], cat_i[order], cat_v[order]
    out = Queue(dists=sd[:L], ids=si[:L], visited=sv[:L])

    best_new = jnp.min(nd)
    # rank of best new candidate = #entries strictly better + existing ties
    # (stable sort places existing entries before new ones on ties).
    better = jnp.sum(cat_d < best_new) + jnp.sum(q.dists == best_new)
    best_rank = jnp.where(jnp.isinf(best_new), L, jnp.minimum(better, L)).astype(jnp.int32)
    n_inserted = jnp.sum((nd < q.dists[L - 1]) & (ni >= 0)).astype(jnp.int32)
    return out, best_rank, n_inserted


def pick_unvisited(q: Queue) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Index of the closest unvisited entry and whether one exists."""
    masked = jnp.where(q.visited, INF, q.dists)
    idx = jnp.argmin(masked).astype(jnp.int32)
    has = jnp.isfinite(masked[idx])
    return idx, has


def mark_visited(q: Queue, idx: jnp.ndarray, do: jnp.ndarray) -> Queue:
    vis = q.visited.at[idx].set(jnp.where(do, True, q.visited[idx]))
    return q._replace(visited=vis)


def topk(q: Queue, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Final result extraction (queue is sorted): first k entries."""
    return q.dists[:k], q.ids[:k]
