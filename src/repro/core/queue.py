"""Fixed-size sorted candidate queue (the priority queue of Algorithm 1).

JAX needs static shapes, so the queue is a struct-of-arrays of length L kept
sorted ascending by distance:

  dists   f32 (L,)  +inf in empty slots
  ids     i32 (L,)  -1   in empty slots
  visited bool (L,) True in empty slots (so they are never expanded)

The queue's single write operation is a *merge*: fold a block of candidate
(dist, id) pairs into the sorted run. Since DESIGN.md §2's beam traversal
the merge is structured as sort-the-new-block + a stable merge of TWO SORTED
RUNS (`merge_sorted_runs`): the new block (W·M entries) is sorted once —
O(WM log WM) on the small block, or inside the fused_expand kernel — and
merged against the already-sorted queue by rank arithmetic, instead of
re-sorting all L+WM entries with a full argsort every step. The merge is
bit-identical to a stable ascending argsort of the concatenation (existing
entries win ties), which is what `merge_insert` produced historically; the
hypothesis suite pins the equivalence against an argsort oracle.

`merge_insert` reports the insertion rank of the best surviving new
candidate — the signal Eq. 3 (early termination) needs; the beam variant
(`merge_insert_beam`) reports one rank per beam expansion, evaluated against
the same merged order (DESIGN.md §2's per-lane ET semantics).

Everything is written for a single query and lifted with jax.vmap by the
search loop.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


class Queue(NamedTuple):
    dists: jnp.ndarray    # (L,) f32, ascending
    ids: jnp.ndarray      # (L,) i32
    visited: jnp.ndarray  # (L,) bool


def init_queue(L: int) -> Queue:
    return Queue(
        dists=jnp.full((L,), INF, dtype=jnp.float32),
        ids=jnp.full((L,), -1, dtype=jnp.int32),
        visited=jnp.ones((L,), dtype=bool),
    )


# --------------------------------------------------------------------------
# Dedupe helpers — ONE copy of the O(M²) lower-triangle logic.
#
# Historically three call sites each re-derived this comparison (the search
# loop's row dedupe, the queue's new-block dedupe, and the bitmap path's
# seen-mask combination); they now all route through dup_prior_mask /
# dedupe_ids, property-tested in tests/test_beam.py.
# --------------------------------------------------------------------------
def dup_prior_mask(ids: jnp.ndarray) -> jnp.ndarray:
    """(M,) ids -> (M,) bool: True where ids[i] equals ids[j] for some
    j < i (strict lower triangle). Negative ids never match anything —
    callers decide separately how to treat invalid slots."""
    m = ids.shape[0]
    tri = jnp.arange(m)[None, :] < jnp.arange(m)[:, None]
    return jnp.any((ids[:, None] == ids[None, :]) & tri & (ids >= 0)[:, None],
                   axis=1)


def dedupe_ids(ids: jnp.ndarray) -> jnp.ndarray:
    """Mask (to -1) ids duplicating an earlier position, and invalid ids."""
    return jnp.where(dup_prior_mask(ids) | (ids < 0), -1, ids)


def in_queue_mask(q: Queue, ids: jnp.ndarray) -> jnp.ndarray:
    """(M,) ids -> (M,) bool: id already present in the queue."""
    return jnp.any(ids[:, None] == q.ids[None, :], axis=1) & (ids >= 0)


def _dedupe_new(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Invalidate new entries that duplicate the queue or earlier new entries."""
    bad = in_queue_mask(q, new_ids) | dup_prior_mask(new_ids) | (new_ids < 0)
    return jnp.where(bad, INF, new_dists), jnp.where(bad, -1, new_ids)


# --------------------------------------------------------------------------
# Sorted-run merge (DESIGN.md §2)
# --------------------------------------------------------------------------
def sort_block(dists: jnp.ndarray, ids: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable ascending sort of a candidate block by distance (ties keep
    block order) — the host-side twin of fused_expand's in-kernel sort."""
    order = jnp.argsort(dists, stable=True)
    return dists[order], ids[order]


def merge_sorted_runs(q: Queue, sd: jnp.ndarray, si: jnp.ndarray) -> Queue:
    """Merge the sorted queue with an ascending-sorted candidate block.

    Rank arithmetic instead of a combined sort: entry i of the queue lands
    at position i + |{block < queue[i]}|, entry j of the block at
    j + |{queue <= block[j]}| — a permutation of [0, L+B), computed with two
    binary searches over already-sorted runs (the XLA lowering of a bitonic
    two-run merge; on TPU the same merge is a log(L+B)-stage compare-exchange
    network). Ties place queue entries first, so the result is bit-identical
    to a stable argsort over the concatenation — no-op merges preserve
    visited flags exactly as before.

    PRECONDITION: `si` is already deduped against the queue and within
    itself (masked entries carry dist=+inf, id=-1) — `merge_insert` /
    `merge_insert_beam` establish this; the fused-expand kernel path does it
    before the kernel computes distances.
    """
    L = q.dists.shape[0]
    B = sd.shape[0]
    pos_q = jnp.arange(L) + jnp.searchsorted(sd, q.dists, side="left")
    pos_b = jnp.arange(B) + jnp.searchsorted(q.dists, sd, side="right")
    n = L + B
    md = jnp.zeros((n,), q.dists.dtype).at[pos_q].set(q.dists).at[pos_b].set(sd)
    mi = jnp.zeros((n,), q.ids.dtype).at[pos_q].set(q.ids).at[pos_b].set(si)
    # new entries enter unvisited; only queue entries carry True flags
    mv = jnp.zeros((n,), bool).at[pos_q].set(q.visited)
    return Queue(dists=md[:L], ids=mi[:L], visited=mv[:L])


def block_ranks(q: Queue, all_dists: jnp.ndarray, bests: jnp.ndarray,
                ties_prior: jnp.ndarray = None) -> jnp.ndarray:
    """Insertion rank of each `bests[w]` in the merged (queue + block)
    order: #entries strictly better + existing-entry ties (stable-sort
    placement), capped at L; +inf (nothing inserted) ranks L.

    `ties_prior (W,)` counts block entries from EARLIER beam expansions
    whose distance exactly ties bests[w]: the stable merge places those
    before w's best, and the as-if-sequential Eq. 3 semantics would have
    inserted them first, so they count toward w's rank (exact ties across
    expansions are real on quantized workloads, e.g. u8-LUT ADC sums).
    None => zeros — correct for W=1, where no earlier expansion exists.

    `all_dists` may be the full deduped block or its sorted top-L prefix —
    ranks at or beyond L saturate identically either way (the prefix holds
    the L best, so any undercount only affects ranks that cap at L).
    """
    L = q.dists.shape[0]
    better = (jnp.sum(q.dists[None, :] < bests[:, None], axis=1)
              + jnp.sum(all_dists[None, :] < bests[:, None], axis=1)
              + jnp.sum(q.dists[None, :] == bests[:, None], axis=1))
    if ties_prior is not None:
        better = better + ties_prior
    return jnp.where(jnp.isinf(bests), L,
                     jnp.minimum(better, L)).astype(jnp.int32)


def beam_tie_counts(block: jnp.ndarray, bests: jnp.ndarray) -> jnp.ndarray:
    """(W, M) block dists, (W,) per-expansion bests -> (W,) counts of
    earlier-expansion entries exactly tying bests[w] (block_ranks'
    ties_prior operand; the fused kernels compute the same in-kernel)."""
    W = block.shape[0]
    eq = jnp.sum(block[None, :, :] == bests[:, None, None], axis=2)  # (W, W')
    tri = jnp.arange(W)[None, :] < jnp.arange(W)[:, None]
    return jnp.sum(jnp.where(tri, eq, 0), axis=1).astype(jnp.int32)


def merge_insert(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray
                 ) -> Tuple[Queue, jnp.ndarray, jnp.ndarray]:
    """Merge (new_dists, new_ids) into the queue.

    Returns (queue', best_rank, n_inserted) where best_rank is the rank (0-
    based position in the merged order) of the best *new* candidate, or L if
    nothing was inserted — the Eq. 3 insertion position p for this step.
    """
    L = q.dists.shape[0]
    nd, ni = _dedupe_new(q, new_dists, new_ids)
    sd, si = sort_block(nd, ni)
    out = merge_sorted_runs(q, sd, si)
    best_rank = block_ranks(q, nd, jnp.min(nd)[None])[0]
    n_inserted = jnp.sum((nd < q.dists[L - 1]) & (ni >= 0)).astype(jnp.int32)
    return out, best_rank, n_inserted


def merge_expand(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray,
                 n_beam: int) -> Tuple[Queue, jnp.ndarray]:
    """Beam merge of a PRE-DEDUPED candidate block: (W·M,) candidates from
    W expansions, flat in beam order (expansion w owns slots
    [w·M, (w+1)·M)), with duplicates / in-queue / invalid entries already
    masked to (dist=+inf, id=-1) — the search loop establishes exactly this
    before the distance step, so re-deriving the O((WM)² + WM·L) dedupe
    masks here would burn the per-iteration fixed cost the beam exists to
    amortize. External callers use merge_insert_beam, which dedupes first.

    Returns (queue', best_ranks (W,)) — best_ranks[w] is the merged-order
    rank of expansion w's best surviving candidate (or L), all evaluated
    against the same post-merge order; the search loop consumes them in
    beam order for Eq. 3 (DESIGN.md §2).
    """
    block = new_dists.reshape(n_beam, -1)
    bests = jnp.min(block, axis=1)
    sd, si = sort_block(new_dists, new_ids)
    out = merge_sorted_runs(q, sd, si)
    return out, block_ranks(q, new_dists, bests,
                            beam_tie_counts(block, bests))


def merge_insert_beam(q: Queue, new_dists: jnp.ndarray, new_ids: jnp.ndarray,
                      n_beam: int) -> Tuple[Queue, jnp.ndarray]:
    """Safe-for-any-input beam merge: _dedupe_new, then merge_expand. With
    n_beam=1 this is exactly merge_insert."""
    nd, ni = _dedupe_new(q, new_dists, new_ids)
    return merge_expand(q, nd, ni, n_beam)


# --------------------------------------------------------------------------
# Expansion picking
# --------------------------------------------------------------------------
def pick_top_w(q: Queue, w: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Slot indices of the w closest unvisited entries, plus existence mask.

    Exploits the sorted-ascending invariant: the w closest unvisited
    candidates are simply the FIRST w unvisited finite slots in queue order
    (no masked argmin over L needed — the scan is a cumulative count).
    Returns (idxs (w,) clamped into [0, L), has (w,) bool); has[j] is False
    (and idxs[j] meaningless) when fewer than j+1 unvisited entries exist.
    """
    L = q.dists.shape[0]
    unv = (~q.visited) & jnp.isfinite(q.dists)
    rank = jnp.cumsum(unv.astype(jnp.int32)) - 1       # rank among unvisited
    take = unv & (rank < w)
    # scatter slot index i to output position rank[i]; non-taken slots
    # target w, which is out of bounds and therefore dropped (jax scatter)
    tgt = jnp.where(take, rank, w)
    idxs = jnp.full((w,), L, jnp.int32).at[tgt].set(
        jnp.arange(L, dtype=jnp.int32), mode="drop")
    has = idxs < L
    return jnp.minimum(idxs, L - 1), has


def pick_unvisited(q: Queue) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Index of the closest unvisited entry and whether one exists —
    pick_top_w with a beam of one (kept for the W=1 callers/tests)."""
    idxs, has = pick_top_w(q, 1)
    return idxs[0], has[0]


def mark_visited(q: Queue, idx: jnp.ndarray, do: jnp.ndarray) -> Queue:
    vis = q.visited.at[idx].set(jnp.where(do, True, q.visited[idx]))
    return q._replace(visited=vis)


def mark_visited_many(q: Queue, idxs: jnp.ndarray, do: jnp.ndarray) -> Queue:
    """Mark several slots at once. idxs may contain clamped duplicates for
    do=False lanes (pick_top_w's sentinel), so the scatter must be an OR —
    an unordered .set of mixed True/False writes to one slot would race."""
    hit = jnp.zeros(q.visited.shape, jnp.int32).at[idxs].add(
        do.astype(jnp.int32))
    return q._replace(visited=q.visited | (hit > 0))


def topk(q: Queue, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Final result extraction (queue is sorted): first k entries."""
    return q.dists[:k], q.ids[:k]
