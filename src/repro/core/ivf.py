"""IVF-PQ index: coarse k-means partitioning + residual product codes.

The partition-based sibling of the graph index (DESIGN.md §4), behind the
same KBest facade. Memory layout is TPU-first: inverted lists are PADDED
DENSE ARRAYS — `list_ids (nlist, max_len)` int32 with -1 padding and
`list_codes (nlist, max_len, m)` uint8 — not ragged CPU-style postings, so a
probed list is one contiguous DMA and the batched ADC scan (H1's 2-D lift)
runs without gather/scatter inside the kernel. `max_len` is padded to the
lane-width multiple (H3 alignment analogue, IVFConfig.list_pad). With
QuantConfig.kind="pq4" (DESIGN.md §13) the fine codes are 4-bit and
nibble-packed — `list_codes (nlist, max_len, m//2)`, half the bytes —
and the scan dispatches to the pq4_ivf_scan kernel. With kind="bin"
(DESIGN.md §14) the lists hold u32-packed sign codes —
`list_codes (nlist, max_len, ceil(d/32))` — and the scan is XOR+popcount
Hamming (bin_ivf_scan) with no LUT stage at all.

Search pipeline (mirrors the three-stage ScaNN/KScaNN shape):
  1. coarse probe: exact query-to-centroid distances, top-nprobe clusters
     (assignment space is L2; for ip/cosine the probe ranking still uses the
     index metric so high-|x| clusters are probed under ip);
  2. fused ADC scan of the probed lists with per-list partial top-L
     (kernels/ivf_scan, jnp reference in kernels/ref.py), then a global
     top-L merge across the nprobe partial lists;
  3. exact re-rank of the survivors from the full-precision vectors — done
     by the caller (KBest._rerank) via the gather_dist path.

Residual encoding (IVFConfig.residual): codes quantize r = x - c(x). For L2
the per-probe LUT is built from q - c_p, so summed ADC approximates
||q - c_p - r_hat||^2 = ||q - x_hat||^2 exactly in PQ's subspace sense. For
ip the LUT is built from q directly (⟨q, x_hat⟩ = ⟨q, c_p⟩ + ⟨q, r_hat⟩)
with the constant ⟨q, c_p⟩ folded into subspace 0 of the table, keeping the
kernel metric-agnostic: it only ever sums m table reads.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core.distance import pairwise
from repro.core.types import IVFConfig, QuantConfig


@dataclasses.dataclass
class IVFState:
    """Built IVF-PQ index (all device arrays; see module docstring)."""

    centroids: jnp.ndarray    # (nlist, d) f32 coarse codebook
    list_ids: jnp.ndarray     # (nlist, max_len) i32, -1 padded
    list_codes: jnp.ndarray   # (nlist, max_len, m) u8 residual PQ codes,
                              # (nlist, max_len, m//2) nibble-packed pq4,
                              # or (nlist, max_len, ceil(d/32)) u32 bin
    pq: Optional[qz.PQState]  # fine codebooks (m, K, ds); K=256 pq / 16
                              # pq4; None for the bin codec
    residual: bool
    packed: bool = False      # True => pq4 nibble-packed list_codes
    bin: Optional[qz.BinState] = None  # set => 1-bit sign codec lists
                                       # (DESIGN.md §14)

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def max_len(self) -> int:
        return self.list_ids.shape[1]


def auto_nlist(n: int) -> int:
    """sqrt(n) heuristic, clamped so tiny corpora still get >= 2 cells."""
    return max(2, min(n, int(round(float(np.sqrt(n))))))


# ---------------------------------------------------------------------- build
def build_ivf(x: jnp.ndarray, ivf_cfg: IVFConfig, quant_cfg: QuantConfig
              ) -> IVFState:
    """Train coarse + fine quantizers and lay out the padded lists.

    Assignment is L2 nearest-centroid regardless of metric (the standard
    IVF choice: residuals stay small, and for cosine the vectors are already
    unit-norm so L2 and angular assignment agree).
    """
    n, d = x.shape
    nlist = ivf_cfg.nlist if ivf_cfg.nlist > 0 else auto_nlist(n)
    nlist = min(nlist, n)
    cents = qz.kmeans(x, nlist, ivf_cfg.kmeans_iters, seed=ivf_cfg.seed)
    assign = jnp.argmin(pairwise(x, cents, "l2"), axis=1)

    if quant_cfg.kind == "bin":
        # 1-bit codec (DESIGN.md §14): signs of the ROTATED RAW vectors,
        # not residuals — Hamming between raw-sign codes is the quantity
        # the rescore bound speaks to, and a shared rotation means one
        # query encoding serves every probed list (no per-probe LUTs)
        pq, packed = None, False
        bin_state = qz.bin_train(x, quant_cfg)
        codes = qz.bin_encode(bin_state, x)             # (n, nw) u32
    else:
        bin_state = None
        vecs = x - cents[assign] if ivf_cfg.residual else x
        pq = qz.pq_train(vecs, quant_cfg)
        packed = quant_cfg.kind == "pq4"
        codes = qz.pq_encode(pq.codebooks, vecs)        # (n, m), values < K
        if packed:
            codes = qz.pq4_pack(codes)                  # (n, m//2)

    # host-side list layout: bucket ids by cluster, pad to a common max_len
    # (vectorized: stable sort by cluster, then scatter each point to its
    # rank within the cluster — no per-point Python loop)
    assign_h = np.asarray(assign)
    codes_h = np.asarray(codes)
    counts = np.bincount(assign_h, minlength=nlist)
    pad = ivf_cfg.list_pad
    max_len = int(-(-max(int(counts.max()), 1) // pad) * pad)
    order = np.argsort(assign_h, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(n) - starts[assign_h[order]]       # rank within cluster
    list_ids = np.full((nlist, max_len), -1, np.int32)
    # dtype follows the codes (u8 for pq/pq4/sq, u32 words for bin)
    list_codes = np.zeros((nlist, max_len, codes_h.shape[1]), codes_h.dtype)
    list_ids[assign_h[order], slot] = order.astype(np.int32)
    list_codes[assign_h[order], slot] = codes_h[order]

    return IVFState(centroids=cents, list_ids=jnp.asarray(list_ids),
                    list_codes=jnp.asarray(list_codes), pq=pq,
                    residual=ivf_cfg.residual, packed=packed,
                    bin=bin_state)


# --------------------------------------------------------------------- search
def select_probes(state: IVFState, q: jnp.ndarray, nprobe: int, metric: str
                  ) -> jnp.ndarray:
    """(Q, d) -> (Q, P) nearest-centroid ids under the index metric."""
    P = min(nprobe, state.nlist)
    d = pairwise(q, state.centroids, metric)
    _, probes = jax.lax.top_k(-d, P)
    return probes.astype(jnp.int32)


def query_luts(state: IVFState, q: jnp.ndarray, probes: jnp.ndarray,
               metric: str, lut_u8: bool = False
               ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """ADC tables (Q, Pl, m, K) plus an optional per-probe bias (Q, P).

    Pl is P only when the table truly differs per probe (l2 residual);
    probe-independent tables stay Pl=1 so the scan kernel never
    materializes nprobe redundant copies. The ip-residual centroid term
    -<q, c_p> is a per-list constant, so it is returned as a separate bias
    added AFTER the per-list partial top-L (a constant shift cannot change
    within-list ranking) rather than folded into the table.
    See the module docstring for the residual/metric algebra.
    """
    Q, P = probes.shape
    books = state.pq.codebooks
    m, K, _ = books.shape
    requant = qz.pq4_requant_lut if lut_u8 else (lambda t: t)
    if metric == "l2" and state.residual:
        cents = state.centroids[probes]                 # (Q, P, d)
        qr = q[:, None, :] - cents
        lut = requant(qz.pq_query_tables(books, qr.reshape(Q * P, -1), "l2"))
        return lut.reshape(Q, P, m, K), None
    lut = requant(qz.pq_query_tables(books, q, metric)).reshape(Q, 1, m, K)
    if metric != "l2" and state.residual:
        bias = -jnp.einsum("qd,qpd->qp", q, state.centroids[probes])
        return lut, bias
    return lut, None


def scan_lists(state: IVFState, luts: jnp.ndarray, probes: jnp.ndarray,
               L: int, impl: str = "ref",
               bias: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused scan + per-list partial top-L, then the global top-L merge.
    Returns (dists (Q, L) ascending approx distances, ids (Q, L), -1 pad)."""
    Lp = min(L, state.max_len)
    if impl == "kernel":
        from repro.kernels import ops as kops
        scan = kops.pq4_ivf_scan if state.packed else kops.ivf_scan
        pd, pi = scan(luts, state.list_codes, state.list_ids, probes, L=Lp)
    else:
        from repro.kernels.ref import ivf_scan_ref, pq4_ivf_scan_ref
        scan = pq4_ivf_scan_ref if state.packed else ivf_scan_ref
        pd, pi = scan(luts, state.list_codes, state.list_ids, probes, Lp)
    if bias is not None:
        pd = pd + bias[:, :, None]      # +inf padding stays +inf
    Q = probes.shape[0]
    flat_d = pd.reshape(Q, -1)                          # (Q, P*Lp)
    flat_i = pi.reshape(Q, -1)
    k = min(L, flat_d.shape[1])
    neg, pos = jax.lax.top_k(-flat_d, k)
    ids = jnp.take_along_axis(flat_i, pos, axis=1)
    return -neg, jnp.where(jnp.isfinite(neg), ids, -1)


def scan_bin_lists(state: IVFState, qcodes: jnp.ndarray,
                   probes: jnp.ndarray, L: int, impl: str = "ref"
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """bin twin of scan_lists: XOR+popcount Hamming over the probed packed
    lists, per-list partial top-L, then the same global top-L merge.
    Returns (dists (Q, L) ascending Hamming, ids (Q, L), -1 pad)."""
    Lp = min(L, state.max_len)
    if impl == "kernel":
        from repro.kernels import ops as kops
        pd, pi = kops.bin_ivf_scan(qcodes, state.list_codes, state.list_ids,
                                   probes, L=Lp)
    else:
        from repro.kernels.ref import bin_ivf_scan_ref
        pd, pi = bin_ivf_scan_ref(qcodes, state.list_codes, state.list_ids,
                                  probes, Lp)
    Q = probes.shape[0]
    flat_d = pd.reshape(Q, -1)                          # (Q, P*Lp)
    flat_i = pi.reshape(Q, -1)
    k = min(L, flat_d.shape[1])
    neg, pos = jax.lax.top_k(-flat_d, k)
    ids = jnp.take_along_axis(flat_i, pos, axis=1)
    return -neg, jnp.where(jnp.isfinite(neg), ids, -1)


def search_ivf(state: IVFState, q: jnp.ndarray, nprobe: int, L: int,
               metric: str, impl: str = "ref", lut_u8: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stages 1+2 of the pipeline: probe, scan, merge.

    Returns (approx dists (Q, L), candidate ids (Q, L), probes (Q, P)) —
    the caller re-ranks the candidates with exact distances (stage 3) and
    can derive scan-cost stats from the probe set (see scanned_counts).

    Traversal-only SearchConfig knobs (`beam_width`, `batch_B`,
    `visited_mode`) do not reach this path: the IVF scan is already a
    dense multi-candidate expansion — every probed list is a "beam slot"
    of max_len candidates — so results are identical for any beam_width
    (pinned in tests/test_beam.py) and only (L, nprobe, dist_impl,
    quant) key its behavior.
    """
    probes = select_probes(state, q, nprobe, metric)
    if state.bin is not None:
        # bin codec: one packed query encoding serves every probed list
        # (no per-probe LUT machinery — DESIGN.md §14)
        qcodes = qz.bin_query_codes(state.bin, q)
        dists, ids = scan_bin_lists(state, qcodes, probes, L, impl)
        return dists, ids, probes
    luts, bias = query_luts(state, q, probes, metric, lut_u8=lut_u8)
    dists, ids = scan_lists(state, luts, probes, L, impl, bias=bias)
    return dists, ids, probes


def scanned_counts(state: IVFState, probes: jnp.ndarray) -> jnp.ndarray:
    """(Q, P) probes -> (Q,) valid codes scanned (stats only — O(index)
    work, so callers should gate it behind their with_stats flag)."""
    n_valid = jnp.sum(state.list_ids >= 0, axis=1)      # (nlist,)
    return jnp.sum(n_valid[probes], axis=1).astype(jnp.int32)
