"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §6).

Parallelism plan over the production mesh (pod?, data, model):

  DP  : batch dims over ("pod", "data")      (pod folds into DP)
  TP  : d_ff / head-flat / vocab over "model" (Megatron column/row split)
  EP  : MoE expert dim over "data"            (all-to-all at dispatch)
  SP  : decode KV-cache sequence over "model" (flash-decoding style)
  ZeRO-1: optimizer moments additionally sharded over DP axes on the
          largest still-replicated divisible dim.

Every rule degrades gracefully: if a dim is not divisible by the mesh axis
size it stays replicated (never a compile error) — per-arch hillclimbs then
override specific rules (launch/dryrun.py --plan).

Specs are produced from *param-tree paths* so the models stay mesh-agnostic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _fits(shape, dim: int, mesh: Mesh, axes) -> bool:
    size = int(np.prod([mesh.shape[a] for a in (
        axes if isinstance(axes, tuple) else (axes,))]))
    return shape[dim] % size == 0


def _spec(shape, assignment: dict, mesh: Mesh) -> P:
    """assignment: {dim_index: axis or tuple-of-axes}; drops non-divisible."""
    parts = [None] * len(shape)
    for dim, ax in assignment.items():
        if ax is not None and _fits(shape, dim, mesh, ax):
            parts[dim] = ax
    return P(*parts)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


# ---------------------------------------------------------------- LM -------
def lm_param_spec(path: str, shape, mesh: Mesh,
                  moe_d_sharded: bool = False) -> P:
    """moe_d_sharded: the shard_map MoE layout — w_in/w_gate sharded on d
    (contraction) instead of f, enabling the small-psum 2-D GEMM; w_out
    stays f-sharded (see layers/moe.moe_ffn_shardmap)."""
    dp = dp_axes(mesh)
    mdl = "model"
    if path.endswith(("embed",)):
        return _spec(shape, {0: mdl}, mesh)                  # (V, d)
    if path.endswith("unembed"):
        return _spec(shape, {1: mdl}, mesh)                  # (d, V)
    if "moe" in path:
        if "shared" in path:
            if path.endswith(("shared_w_in", "shared_w_gate")):
                return _spec(shape, {2: mdl}, mesh)          # (L, d, fs)
            if path.endswith("shared_w_out"):
                return _spec(shape, {1: mdl}, mesh)          # (L, fs, d)
            return P()
        # stacked (L, E, ...) expert weights: EP over data, TP over model
        if path.endswith(("w_in", "w_gate")):
            dim = 2 if moe_d_sharded else 3                  # (L, E, d, f)
            return _spec(shape, {1: "data", dim: mdl}, mesh)
        if path.endswith("w_out"):
            return _spec(shape, {1: "data", 2: mdl}, mesh)   # (L, E, f, d)
        return P()                                           # router, biases
    if path.endswith(("wq", "wk", "wv")):
        return _spec(shape, {2: mdl}, mesh)                  # (L, d, H*hd)
    if path.endswith("wo"):
        return _spec(shape, {1: mdl}, mesh)                  # (L, H*hd, d)
    if path.endswith(("w_in", "w_gate")):
        return _spec(shape, {2: mdl}, mesh)                  # (L, d, f)
    if path.endswith("w_out"):
        return _spec(shape, {1: mdl}, mesh)                  # (L, f, d)
    return P()                                               # norms, biases


def lm_batch_spec(shape, mesh: Mesh) -> P:
    return _spec(shape, {0: dp_axes(mesh)}, mesh)


def lm_cache_shardings(cache_tree, mesh: Mesh) -> dict:
    """KV cache (L, B, T, Hkv, hd): batch over DP + sequence over model
    (flash-decoding style SP). When B doesn't divide the DP axes (long_500k
    has B=1), the sequence dim absorbs ALL axes instead — 524288 % 512 == 0.
    kv_len (B,): DP."""
    dp = dp_axes(mesh)
    all_axes = dp + ("model",)

    def spec(path, leaf):
        ps = _path_str(path)
        if ps.endswith("len"):
            return NamedSharding(mesh, _spec(leaf.shape, {0: dp}, mesh))
        if _fits(leaf.shape, 1, mesh, dp):
            return NamedSharding(
                mesh, _spec(leaf.shape, {1: dp, 2: "model"}, mesh))
        return NamedSharding(mesh, _spec(leaf.shape, {2: all_axes}, mesh))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


# ------------------------------------------------------------- recsys ------
def recsys_param_spec(path: str, shape, mesh: Mesh) -> P:
    mdl = "model"
    if path.endswith("tables"):
        return _spec(shape, {1: mdl}, mesh)                  # (F, V, D)
    if path.endswith("linear") and len(shape) == 2:
        return _spec(shape, {1: mdl}, mesh)                  # (F, V)
    if path.endswith(("item_emb",)):
        return _spec(shape, {0: mdl}, mesh)                  # (V, Dm)
    if path.endswith("w") and len(shape) == 2:
        return _spec(shape, {1: mdl}, mesh)                  # MLP columns
    return P()


def recsys_batch_spec(shape, mesh: Mesh) -> P:
    return _spec(shape, {0: dp_axes(mesh)}, mesh)


# -------------------------------------------------------------- dimenet ----
def dimenet_param_spec(path: str, shape, mesh: Mesh) -> P:
    return P()   # parameters are tiny; data parallelism over edges instead


def dimenet_batch_spec(path: str, shape, mesh: Mesh,
                       shard_all_axes: bool = False) -> P:
    """Node/edge/triplet arrays row-sharded over DP; with shard_all_axes
    (hillclimb B) rows spread over EVERY mesh axis — 16x less resident
    bytes per device on ogb_products' 495M-triplet arrays at the price of
    all-gathers on the node-feature gathers (measured in §Perf)."""
    axes = dp_axes(mesh) + ("model",) if shard_all_axes else dp_axes(mesh)
    return _spec(shape, {0: axes}, mesh)


# ---------------------------------------------------------------- trees ----
def tree_param_shardings(params_or_shapes, mesh: Mesh, family: str,
                         moe_d_sharded: bool = False):
    fn = {"lm": lm_param_spec, "recsys": recsys_param_spec,
          "gnn": dimenet_param_spec}[family]

    def spec(path, leaf):
        if family == "lm":
            return NamedSharding(mesh, fn(_path_str(path), leaf.shape, mesh,
                                          moe_d_sharded))
        return NamedSharding(mesh, fn(_path_str(path), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, params_or_shapes)


def tree_batch_shardings(batch, mesh: Mesh, family: str,
                         gnn_shard_all: bool = False):
    def spec(path, leaf):
        if family == "gnn":
            return NamedSharding(
                mesh, dimenet_batch_spec(_path_str(path), leaf.shape, mesh,
                                         gnn_shard_all))
        if family == "recsys":
            return NamedSharding(mesh, recsys_batch_spec(leaf.shape, mesh))
        return NamedSharding(mesh, lm_batch_spec(leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch)


def zero1_state_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """Optimizer-moment sharding: param spec + DP over the largest
    still-replicated divisible dim (ZeRO-1). Mesh axes already consumed by
    the param spec (e.g. EP over "data" for expert weights) are excluded."""
    parts = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            if a is not None:
                used.add(a)
    dp = tuple(a for a in dp_axes(mesh) if a not in used)
    if not dp:
        return P(*parts)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    best, best_dim = 0, -1
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % dp_size == 0 and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        parts[best_dim] = dp if len(dp) > 1 else dp[0]
    return P(*parts)
