"""batch_dist — tiled Q-to-B distance-matrix kernel (paper H1 on the MXU).

The paper's core SIMD trick is turning scalar 1-to-1 distances into batched
1-to-B NEON operations. On TPU the isomorphic move is a 2-D lift: tile the
(Q, B) distance matrix so every (TQ, TB) output tile is produced by one MXU
contraction q_tile @ x_tile^T held in VMEM, with the rank-1 norm corrections
(for L2) computed on the VPU in the same kernel invocation — a single fused
pass, the analogue of the paper's vmlaq_f32 fused multiply-accumulate.

Grid: (Q/TQ, B/TB); d is kept whole per tile (ANNS dims are <= ~1k, so a
(TQ, d) tile is <= 128*1024*4B = 512 KiB — comfortably inside VMEM).

Alignment (paper H3 analogue): callers pad d to a multiple of 128 (lane
width) and Q/B to the tile multiples; zero-padding is exact for both l2 and
ip (padded coordinates contribute 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_l2(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # (TQ, d)
    x = x_ref[...].astype(jnp.float32)            # (TB, d)
    qx = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    qq = jnp.sum(q * q, axis=1, keepdims=True)    # (TQ, 1)
    xx = jnp.sum(x * x, axis=1)[None, :]          # (1, TB)
    o_ref[...] = jnp.maximum(qq + xx - 2.0 * qx, 0.0)


def _kernel_ip(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = -jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("metric", "tq", "tb", "interpret"))
def batch_dist(q: jnp.ndarray, x: jnp.ndarray, *, metric: str = "l2",
               tq: int = 128, tb: int = 128, interpret: bool = False
               ) -> jnp.ndarray:
    """(Q, d) x (B, d) -> (Q, B). Q, B, d must already be tile-aligned."""
    Q, d = q.shape
    B, d2 = x.shape
    assert d == d2 and Q % tq == 0 and B % tb == 0, (q.shape, x.shape, tq, tb)
    kernel = _kernel_l2 if metric == "l2" else _kernel_ip
    return pl.pallas_call(
        kernel,
        grid=(Q // tq, B // tb),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, B), jnp.float32),
        interpret=interpret,
    )(q, x)
