"""traverse_step — fused beam-expansion kernels (DESIGN.md §2, H1 + H2).

One traversal iteration of the beam search expands W nodes per query and
needs the distances of all W·M gathered neighbors as a SORTED candidate
block (the queue then merges two sorted runs instead of re-sorting L+W·M
entries). These kernels fuse the three steps that used to be separate XLA
ops — gather, distance, block sort — into one Pallas pipeline per query:

  * gather: the W·M candidate rows (full vectors, SQ codes, or PQ/PQ4 code
    words) are DMA'd one grid step ahead by the scalar-prefetched id array —
    the same double-buffered H2 prefetch discipline as gather_dist, now with
    W·M rows in flight per query so the pipeline always has enough
    outstanding DMAs to hide HBM latency behind compute;
  * distance: computed on-chip as each row lands (H1), accumulated into a
    VMEM scratch row — per-family math matches gather_dist / sq_gather_dist
    / pq_adc / pq4_adc exactly;
  * sort + reduce: on the last grid step of each query the scratch row is
    masked (invalid ids -> +inf), stably sorted, and only the top
    T = min(L, W·M) candidates leave the kernel (the rest can never survive
    the queue merge), plus the per-expansion minima `bests (W,)` — the
    operand Eq. 3's per-lane early termination consumes in beam order.

Grid: (Q, C) with C = W·M. Outputs are written once per query, on step
C−1; the (1, T) output blocks are indexed by query only, so they stay
resident across the C steps. The in-kernel sort is jax.lax.sort
(is_stable=True, so ties keep flat beam order — bit-compatible with the
host-side sort_block + merge path); interpret mode executes it directly,
Mosaic lowers it via a bitonic network — keep T a power of two there, as
with ivf_scan's top_k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _finalize(i, idx_ref, acc_ref, od_ref, oi_ref, ob_ref, ot_ref, *,
              T: int, W: int):
    """Mask, sort, truncate, per-expansion minima + earlier-expansion tie
    counts (shared epilogue; the tie counts are block_ranks' ties_prior —
    entries of earlier beam expansions exactly tying expansion w's best
    precede it in the stable merge, so Eq. 3's rank must include them).

    `i` (the query grid index) is computed by the caller OUTSIDE the
    pl.when region — program_id inside a cond branch has no interpret-mode
    lowering."""
    ids_row = idx_ref[i, :]                              # (C,)
    d = jnp.where(ids_row >= 0, acc_ref[0, :], jnp.inf)
    sd, si = jax.lax.sort((d, ids_row), is_stable=True, num_keys=1)
    od_ref[...] = sd[:T].reshape(1, T)
    oi_ref[...] = jnp.where(jnp.isfinite(sd[:T]), si[:T], -1).reshape(1, T)
    block = d.reshape(W, -1)
    bests = jnp.min(block, axis=1)
    ob_ref[...] = bests.reshape(1, W)
    eq = jnp.sum((block[None, :, :] == bests[:, None, None]), axis=2)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (W, W), 1)
           < jax.lax.broadcasted_iota(jnp.int32, (W, W), 0))
    ot_ref[...] = jnp.sum(jnp.where(tri, eq, 0),
                          axis=1).astype(jnp.int32).reshape(1, W)


def _out_shapes(Q: int, T: int, W: int):
    return [jax.ShapeDtypeStruct((Q, T), jnp.float32),
            jax.ShapeDtypeStruct((Q, T), jnp.int32),
            jax.ShapeDtypeStruct((Q, W), jnp.float32),
            jax.ShapeDtypeStruct((Q, W), jnp.int32)]


def _out_specs(T: int, W: int):
    return [pl.BlockSpec((1, T), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, T), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, W), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, W), lambda i, j, idx_ref: (i, 0))]


# ------------------------------------------------------------- full vectors
def _make_full_kernel(metric: str, C: int, T: int, W: int):
    def kernel(idx_ref, q_ref, row_ref, od_ref, oi_ref, ob_ref,
               ot_ref, acc_ref):
        i, j = pl.program_id(0), pl.program_id(1)
        q = q_ref[...].astype(jnp.float32)               # (1, d)
        r = row_ref[...].astype(jnp.float32)             # (1, d) gathered
        if metric == "l2":
            diff = r - q
            acc_ref[0, j] = jnp.sum(diff * diff)
        else:
            acc_ref[0, j] = -jnp.sum(r * q)

        @pl.when(j == C - 1)
        def _():
            _finalize(i, idx_ref, acc_ref, od_ref, oi_ref, ob_ref, ot_ref,
                      T=T, W=W)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("metric", "L", "n_beam", "interpret"))
def fused_expand(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray, *,
                 metric: str = "l2", L: int, n_beam: int = 1,
                 interpret: bool = False):
    """(Q, d) queries, (n, d) db, (Q, C) ids -> sorted candidate block
    (dists (Q, T) ascending, ids (Q, T), bests (Q, n_beam), earlier-
    expansion tie counts (Q, n_beam)); T = min(L, C). ids < 0 are clamped
    for the DMA and come back as (+inf, -1)."""
    Q, d = q.shape
    C = ids.shape[1]
    assert ids.shape[0] == Q and C % n_beam == 0, (ids.shape, n_beam)
    T = min(L, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
            # the H2 prefetch gather: step (i, j)'s row is idx[i, j], DMA'd
            # one step ahead by the pipeline engine. The prefetch operand
            # carries the RAW ids (the epilogue masks on sign), so the DMA
            # clamp lives in the index map.
            pl.BlockSpec((1, d),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i, j], 0), 0)),
        ],
        out_specs=_out_specs(T, n_beam),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
    )
    return pl.pallas_call(
        _make_full_kernel(metric, C, T, n_beam),
        grid_spec=grid_spec,
        out_shape=_out_shapes(Q, T, n_beam),
        interpret=interpret,
    )(ids, q, db)


# ----------------------------------------------------------------- SQ codes
def _make_sq_kernel(metric: str, C: int, T: int, W: int):
    def kernel(idx_ref, q_ref, row_ref, scale_ref, zero_ref,
               od_ref, oi_ref, ob_ref, ot_ref, acc_ref):
        i, j = pl.program_id(0), pl.program_id(1)
        q = q_ref[...].astype(jnp.float32)
        r = (row_ref[...].astype(jnp.float32) * scale_ref[...]
             + zero_ref[...])                            # in-VMEM dequant
        if metric == "l2":
            diff = r - q
            acc_ref[0, j] = jnp.sum(diff * diff)
        else:
            acc_ref[0, j] = -jnp.sum(r * q)

        @pl.when(j == C - 1)
        def _():
            _finalize(i, idx_ref, acc_ref, od_ref, oi_ref, ob_ref, ot_ref,
                      T=T, W=W)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("metric", "L", "n_beam", "interpret"))
def fused_expand_sq(q: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                    zero: jnp.ndarray, ids: jnp.ndarray, *,
                    metric: str = "l2", L: int, n_beam: int = 1,
                    interpret: bool = False):
    """SQ twin of fused_expand: u8 rows gathered (quarter the DMA traffic of
    f32), affine-dequantized in VMEM, same sorted-block epilogue."""
    Q, d = q.shape
    C = ids.shape[1]
    assert ids.shape[0] == Q and codes.shape[1] == d
    assert scale.shape == (1, d) and zero.shape == (1, d)
    assert C % n_beam == 0, (C, n_beam)
    T = min(L, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, d),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i, j], 0), 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (0, 0)),
        ],
        out_specs=_out_specs(T, n_beam),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
    )
    return pl.pallas_call(
        _make_sq_kernel(metric, C, T, n_beam),
        grid_spec=grid_spec,
        out_shape=_out_shapes(Q, T, n_beam),
        interpret=interpret,
    )(ids, q, codes, scale, zero)


# ----------------------------------------------------------------- PQ codes
def _make_pq_kernel(C: int, T: int, W: int, packed: bool):
    def kernel(idx_ref, lut_ref, code_ref, od_ref, oi_ref, ob_ref,
               ot_ref, acc_ref):
        i, j = pl.program_id(0), pl.program_id(1)
        lut = lut_ref[...].astype(jnp.float32)           # (1, m, K)
        m, K = lut.shape[1], lut.shape[2]
        if packed:
            p = code_ref[...].astype(jnp.int32)          # (1, m//2) bytes
            code = jnp.stack([p & 0x0F, (p >> 4) & 0x0F],
                             axis=-1).reshape(1, m)      # nibble unpack
        else:
            code = code_ref[...].astype(jnp.int32)       # (1, m)
        # gather-as-matmul: one-hot (m, K) against the LUT (same MXU idiom
        # as pq_adc; K=16 keeps the pq4 table VMEM/register resident)
        onehot = (code[0, :, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (m, K), 1)
                  ).astype(jnp.float32)
        acc_ref[0, j] = jnp.sum(lut[0] * onehot)

        @pl.when(j == C - 1)
        def _():
            _finalize(i, idx_ref, acc_ref, od_ref, oi_ref, ob_ref, ot_ref,
                      T=T, W=W)
    return kernel


@functools.partial(jax.jit, static_argnames=("L", "n_beam", "interpret"))
def fused_expand_pq(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray,
                    *, L: int, n_beam: int = 1, interpret: bool = False):
    """PQ-ADC twin of fused_expand: (Q, m, K) luts, (n, m) u8 codes; code
    rows stream by scalar-prefetch while the (m, K) LUT stays resident."""
    Q, m, K = lut.shape
    C = ids.shape[1]
    assert ids.shape[0] == Q and C % n_beam == 0, (ids.shape, n_beam)
    T = min(L, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, C),
        in_specs=[
            pl.BlockSpec((1, m, K), lambda i, j, idx_ref: (i, 0, 0)),
            pl.BlockSpec((1, m),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i, j], 0), 0)),
        ],
        out_specs=_out_specs(T, n_beam),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
    )
    return pl.pallas_call(
        _make_pq_kernel(C, T, n_beam, packed=False),
        grid_spec=grid_spec,
        out_shape=_out_shapes(Q, T, n_beam),
        interpret=interpret,
    )(ids, lut, codes)


@functools.partial(jax.jit, static_argnames=("L", "n_beam", "interpret"))
def fused_expand_pq4(lut: jnp.ndarray, packed: jnp.ndarray,
                     ids: jnp.ndarray, *, L: int, n_beam: int = 1,
                     interpret: bool = False):
    """PQ4 twin: (Q, m, 16) luts, (n, m//2) nibble-packed u8 codes — half
    the code DMA bytes of fused_expand_pq, unpacked in-kernel."""
    Q, m, K = lut.shape
    C = ids.shape[1]
    assert K == 16 and packed.shape[1] * 2 == m, (lut.shape, packed.shape)
    assert ids.shape[0] == Q and C % n_beam == 0, (ids.shape, n_beam)
    T = min(L, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, C),
        in_specs=[
            pl.BlockSpec((1, m, K), lambda i, j, idx_ref: (i, 0, 0)),
            pl.BlockSpec((1, m // 2),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i, j], 0), 0)),
        ],
        out_specs=_out_specs(T, n_beam),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
    )
    return pl.pallas_call(
        _make_pq_kernel(C, T, n_beam, packed=True),
        grid_spec=grid_spec,
        out_shape=_out_shapes(Q, T, n_beam),
        interpret=interpret,
    )(ids, lut, packed)
