"""gather_dist — scalar-prefetch gather + distance kernel (paper H2 on TPU).

The paper's software prefetch (`prfm PLDL1KEEP`) hides HBM latency by
requesting neighbor vectors before the compute that needs them. TPUs have no
cache-prefetch instruction; the native equivalent is the Pallas pipeline
engine itself: when an input's BlockSpec index_map depends on a
*scalar-prefetch* operand, the engine reads the index array ahead of the
grid and issues the HBM->VMEM DMA for step (i+1)'s block while step i's
compute runs — automatic double buffering driven by the neighbor-id array,
i.e. exactly "prefetch the adjacency targets of the node being expanded"
(paper Fig. 5) expressed structurally.

Grid: (Q, M/TB). Per step the engine gathers a row-block of TB neighbor
vectors (TB rows DMA'd by index) and the kernel computes TB distances to the
query row. Invalid ids (< 0, CSR padding) are clamped for the DMA and masked
to +inf by the wrapper in ops.py.

NOTE on granularity: one grid step per (query, neighbor-block) keeps each
DMA a contiguous (TB, d) region only when neighbor ids are contiguous after
graph reordering (A2!) — otherwise the engine issues TB row-DMAs. Either
way compute/DMA overlap is preserved; the reorder benefit shows up as fewer
distinct pages per step (benchmarks/ablation.py `locality`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_l2(idx_ref, q_ref, row_ref, o_ref):
    # q_ref: (1, d); row_ref: (1, d) — the gathered neighbor vector
    q = q_ref[...].astype(jnp.float32)
    r = row_ref[...].astype(jnp.float32)
    diff = r - q
    o_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


def _kernel_ip(idx_ref, q_ref, row_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    r = row_ref[...].astype(jnp.float32)
    o_ref[...] = -jnp.sum(r * q, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_dist(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray, *,
                metric: str = "l2", interpret: bool = False) -> jnp.ndarray:
    """(Q, d) queries, (n, d) db, (Q, M) int32 ids -> (Q, M) f32 distances.

    ids < 0 are treated as 0 for the gather; the caller masks them. d must
    be lane-aligned (multiple of 128 on real hardware).
    """
    Q, d = q.shape
    M = ids.shape[1]
    assert ids.shape[0] == Q
    safe_ids = jnp.maximum(ids, 0)
    kernel = _kernel_l2 if metric == "l2" else _kernel_ip

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
            # the prefetch-driven gather: the DB block for step (i, j) is
            # row idx[i, j]; the pipeline engine DMAs it one step ahead.
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, M), jnp.float32),
        interpret=interpret,
    )(safe_ids, q, db)
    return jnp.where(ids >= 0, out, jnp.inf)


# ---------------------------------------------------------- fused SQ variant
def _sq_kernel_l2(idx_ref, q_ref, row_ref, scale_ref, zero_ref, o_ref):
    # row_ref: (1, d) uint8 codes — dequantized in VMEM, never materialized
    # as an f32 database (the whole point of the SQ store)
    q = q_ref[...].astype(jnp.float32)
    r = (row_ref[...].astype(jnp.float32) * scale_ref[...] + zero_ref[...])
    diff = r - q
    o_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


def _sq_kernel_ip(idx_ref, q_ref, row_ref, scale_ref, zero_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    r = (row_ref[...].astype(jnp.float32) * scale_ref[...] + zero_ref[...])
    o_ref[...] = -jnp.sum(r * q, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def sq_gather_dist(q: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, ids: jnp.ndarray, *,
                   metric: str = "l2", interpret: bool = False) -> jnp.ndarray:
    """Fused SQ gather + dequant + distance (the kernel path sq_make_dist_fn
    used to silently skip). Same prefetch-gather structure as gather_dist,
    but the DMA'd rows are (1, d) uint8 — a quarter of the f32 traffic —
    and the affine dequant (code * scale + zero) runs in-kernel against the
    VMEM-resident (1, d) scale/zero rows.

    q (Q, d) f32, codes (n, d) u8, scale/zero (1, d) f32, ids (Q, M) i32.
    """
    Q, d = q.shape
    M = ids.shape[1]
    assert ids.shape[0] == Q and codes.shape[1] == d
    assert scale.shape == (1, d) and zero.shape == (1, d)
    safe_ids = jnp.maximum(ids, 0)
    kernel = _sq_kernel_l2 if metric == "l2" else _sq_kernel_ip

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, M), jnp.float32),
        interpret=interpret,
    )(safe_ids, q, codes, scale, zero)
    return jnp.where(ids >= 0, out, jnp.inf)
