"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification; tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp


def batch_dist_ref(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(Q, d), (B, d) -> (Q, B) distance matrix."""
    if metric == "l2":
        qq = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
        xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[None, :]
        qx = q.astype(jnp.float32) @ x.astype(jnp.float32).T
        return jnp.maximum(qq + xx - 2.0 * qx, 0.0)
    return -(q.astype(jnp.float32) @ x.astype(jnp.float32).T)


def gather_dist_ref(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray,
                    metric: str) -> jnp.ndarray:
    """(Q, d) queries, (n, d) db, (Q, M) ids -> (Q, M) distances.

    Invalid ids (< 0) produce +inf.
    """
    vecs = db[jnp.maximum(ids, 0)].astype(jnp.float32)        # (Q, M, d)
    qf = q.astype(jnp.float32)
    if metric == "l2":
        diff = vecs - qf[:, None, :]
        out = jnp.sum(diff * diff, axis=-1)
    else:
        out = -jnp.einsum("qmd,qd->qm", vecs, qf)
    return jnp.where(ids >= 0, out, jnp.inf)


def sq_gather_dist_ref(q: jnp.ndarray, codes: jnp.ndarray,
                       scale: jnp.ndarray, zero: jnp.ndarray,
                       ids: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(Q, d) queries, (n, d) u8 codes, (1, d) scale/zero, (Q, M) ids ->
    (Q, M) distances against the affine-dequantized rows
    (code * scale + zero). Invalid ids (< 0) produce +inf.
    """
    vecs = (codes[jnp.maximum(ids, 0)].astype(jnp.float32)
            * scale.reshape(-1)[None, None, :]
            + zero.reshape(-1)[None, None, :])
    qf = q.astype(jnp.float32)
    if metric == "l2":
        diff = vecs - qf[:, None, :]
        out = jnp.sum(diff * diff, axis=-1)
    else:
        out = -jnp.einsum("qmd,qd->qm", vecs, qf)
    return jnp.where(ids >= 0, out, jnp.inf)


def pq_adc_ref(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray
               ) -> jnp.ndarray:
    """(Q, m, K) luts, (n, m) uint8 codes, (Q, B) ids -> (Q, B) ADC dists.

    dist[q, b] = sum_j lut[q, j, codes[ids[q, b], j]]; invalid ids -> +inf.
    """
    c = codes[jnp.maximum(ids, 0)].astype(jnp.int32)          # (Q, B, m)
    g = jnp.take_along_axis(lut[:, None, :, :], c[..., None], axis=-1)[..., 0]
    out = jnp.sum(g, axis=-1)
    return jnp.where(ids >= 0, out, jnp.inf)


def _unpack_nibbles_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., m//2) packed bytes -> (..., m) i32 codes (low nibble first)."""
    p = packed.astype(jnp.int32)
    lo = p & 0x0F
    hi = (p >> 4) & 0x0F
    return jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (2 * packed.shape[-1],))


def pq4_adc_ref(lut: jnp.ndarray, packed: jnp.ndarray, ids: jnp.ndarray
                ) -> jnp.ndarray:
    """(Q, m, 16) luts, (n, m//2) u8 nibble-packed codes, (Q, B) ids ->
    (Q, B) ADC dists; invalid ids -> +inf. Unpack-then-pq_adc semantics."""
    c = _unpack_nibbles_ref(packed[jnp.maximum(ids, 0)])      # (Q, B, m)
    g = jnp.take_along_axis(lut[:, None, :, :], c[..., None], axis=-1)[..., 0]
    out = jnp.sum(g, axis=-1)
    return jnp.where(ids >= 0, out, jnp.inf)


def sorted_block_ref(d: jnp.ndarray, ids: jnp.ndarray, L: int, n_beam: int):
    """Shared epilogue of the fused_expand family: mask invalid ids to
    +inf, stable-sort ascending (ties keep flat beam order), truncate to
    T = min(L, C), and report each beam expansion's best (minimum) distance
    plus its earlier-expansion exact-tie count (queue.block_ranks'
    ties_prior operand — Eq. 3 must rank a best behind same-iteration
    earlier-expansion entries that tie it).

    d (Q, C), ids (Q, C) with C divisible by n_beam ->
    (dists (Q, T) ascending, ids (Q, T) with -1 beyond the finite prefix,
    bests (Q, n_beam), ties (Q, n_beam) i32).
    """
    Q, C = d.shape
    T = min(L, C)
    d = jnp.where(ids >= 0, d, jnp.inf)
    order = jnp.argsort(d, axis=1, stable=True)
    sd = jnp.take_along_axis(d, order, axis=1)[:, :T]
    si = jnp.take_along_axis(ids, order, axis=1)[:, :T]
    si = jnp.where(jnp.isfinite(sd), si, -1)
    block = d.reshape(Q, n_beam, -1)
    bests = jnp.min(block, axis=2)
    eq = jnp.sum(block[:, None, :, :] == bests[:, :, None, None], axis=3)
    tri = (jnp.arange(n_beam)[None, :] < jnp.arange(n_beam)[:, None])[None]
    ties = jnp.sum(jnp.where(tri, eq, 0), axis=2).astype(jnp.int32)
    return sd, si, bests, ties


def fused_expand_ref(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray,
                     metric: str, L: int, n_beam: int = 1):
    """(Q, d), (n, d), (Q, C) -> sorted top-min(L, C) candidate block +
    per-expansion bests; gather_dist then the sorted-block epilogue."""
    return sorted_block_ref(gather_dist_ref(q, db, ids, metric), ids,
                            L, n_beam)


def fused_expand_sq_ref(q: jnp.ndarray, codes: jnp.ndarray,
                        scale: jnp.ndarray, zero: jnp.ndarray,
                        ids: jnp.ndarray, metric: str, L: int,
                        n_beam: int = 1):
    """SQ twin: sq_gather_dist_ref then the sorted-block epilogue."""
    d = sq_gather_dist_ref(q, codes, scale, zero, ids, metric)
    return sorted_block_ref(d, ids, L, n_beam)


def fused_expand_pq_ref(lut: jnp.ndarray, codes: jnp.ndarray,
                        ids: jnp.ndarray, L: int, n_beam: int = 1):
    """PQ-ADC twin: pq_adc_ref then the sorted-block epilogue."""
    return sorted_block_ref(pq_adc_ref(lut, codes, ids), ids, L, n_beam)


def fused_expand_pq4_ref(lut: jnp.ndarray, packed: jnp.ndarray,
                         ids: jnp.ndarray, L: int, n_beam: int = 1):
    """PQ4 twin: pq4_adc_ref then the sorted-block epilogue."""
    return sorted_block_ref(pq4_adc_ref(lut, packed, ids), ids, L, n_beam)


def pq4_ivf_scan_ref(luts: jnp.ndarray, list_codes: jnp.ndarray,
                     list_ids: jnp.ndarray, probe_ids: jnp.ndarray, L: int):
    """pq4 twin of ivf_scan_ref: (nlist, max_len, m//2) packed list codes
    are unpacked to (nlist, max_len, m) and scanned identically."""
    return ivf_scan_ref(luts, _unpack_nibbles_ref(list_codes), list_ids,
                        probe_ids, L)


def bin_dist_ref(qcodes: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray
                 ) -> jnp.ndarray:
    """(Q, nw) u32 packed query signs, (n, nw) u32 packed db signs, (Q, B)
    ids -> (Q, B) f32 Hamming distances (XOR + popcount); invalid ids ->
    +inf. Tail bits past d are zero on both sides, so they never count."""
    import jax

    c = codes[jnp.maximum(ids, 0)]                    # (Q, B, nw)
    x = jnp.bitwise_xor(c, qcodes[:, None, :])
    out = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.float32)
    return jnp.where(ids >= 0, out, jnp.inf)


def fused_expand_bin_ref(qcodes: jnp.ndarray, codes: jnp.ndarray,
                         ids: jnp.ndarray, L: int, n_beam: int = 1):
    """bin twin: bin_dist_ref then the sorted-block epilogue."""
    return sorted_block_ref(bin_dist_ref(qcodes, codes, ids), ids, L, n_beam)


def bin_ivf_scan_ref(qcodes: jnp.ndarray, list_codes: jnp.ndarray,
                     list_ids: jnp.ndarray, probe_ids: jnp.ndarray, L: int):
    """bin twin of ivf_scan_ref: (Q, nw) u32 packed queries against
    (nlist, max_len, nw) u32 packed list codes; XOR+popcount Hamming,
    padding (-1) masked to +inf, per-list top-L."""
    import jax

    codes = list_codes[probe_ids]                     # (Q, P, max_len, nw)
    ids = list_ids[probe_ids]                         # (Q, P, max_len)
    x = jnp.bitwise_xor(codes, qcodes[:, None, None, :])
    d = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.float32)
    d = jnp.where(ids >= 0, d, jnp.inf)
    neg, pos = jax.lax.top_k(-d, L)
    out_ids = jnp.take_along_axis(ids, pos, axis=-1)
    return -neg, jnp.where(jnp.isfinite(neg), out_ids, -1)


def ivf_scan_ref(luts: jnp.ndarray, list_codes: jnp.ndarray,
                 list_ids: jnp.ndarray, probe_ids: jnp.ndarray, L: int):
    """(Q, Pl, m, K) luts (Pl = P, or 1 for probe-independent tables),
    (nlist, max_len, m) codes, (nlist, max_len) ids, (Q, P) probes ->
    per-list top-L (dists (Q, P, L) ascending, ids (Q, P, L)).

    ADC over every code of every probed list, padding (-1) masked to +inf,
    then each list independently reduced to its L best — the semantic spec
    of ivf_scan's fused scan + partial reduction.
    """
    import jax

    P = probe_ids.shape[1]
    if luts.shape[1] == 1 and P > 1:
        luts = jnp.broadcast_to(luts, (luts.shape[0], P) + luts.shape[2:])
    codes = list_codes[probe_ids].astype(jnp.int32)   # (Q, P, max_len, m)
    ids = list_ids[probe_ids]                         # (Q, P, max_len)
    g = jnp.take_along_axis(luts[:, :, None, :, :],   # (Q, P, 1, m, K)
                            codes[..., None], axis=-1)[..., 0]
    d = jnp.sum(g, axis=-1)                           # (Q, P, max_len)
    d = jnp.where(ids >= 0, d, jnp.inf)
    neg, pos = jax.lax.top_k(-d, L)
    out_ids = jnp.take_along_axis(ids, pos, axis=-1)
    return -neg, jnp.where(jnp.isfinite(neg), out_ids, -1)
