"""bin_hamming — 1-bit XOR+popcount Hamming kernels (DESIGN.md §14).

The binary codec (core/quantize.py: kind="bin") stores one sign bit per
rotated dimension, packed 32 to a uint32 word — d=128 vectors become 4
words (16 bytes), 8x smaller than per-dimension 8-bit PQ codes and 32x
smaller than f32. Hamming distance between two packed codes is
popcount(XOR), an exact integer, so these kernels are bit-identical to
their jnp oracles (ref.py) and parity tests assert ==, not allclose.

Three kernels mirror the pq4 family one-for-one:

  bin_dist        — graph-path gather Hamming, grid (Q, B): the packed
                    code row of neighbor ids[q, b] streams by scalar
                    prefetch (H2) against the query's VMEM-resident
                    packed code — the per-row DMA is nw u32 words (16
                    bytes at d=128), the smallest gather in the system.
  fused_expand_bin — fused traversal step, grid (Q, C): Hamming into the
                    VMEM scratch row per candidate, then the shared
                    sorted-block epilogue (traverse_step._finalize) on
                    the last step — identical queue contract to
                    fused_expand_pq4.
  bin_ivf_scan    — IVF list scan + per-list partial top-L, grid (Q, P).

Popcount is the SWAR bit-ladder (no LUT, no popcount intrinsic needed):
pairs, nibbles, bytes, then a *0x01010101 horizontal byte-sum — pure
shift/mask/add uint32 VPU ops, exact by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.traverse_step import _finalize, _out_shapes, _out_specs


def _popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element bit count of a uint32 array (SWAR ladder, exact)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24          # byte-sum in the top byte


# ------------------------------------------------------------ graph gather
def _dist_kernel(idx_ref, q_ref, code_ref, o_ref):
    x = jnp.bitwise_xor(q_ref[...], code_ref[...])     # (1, nw) u32
    o_ref[...] = jnp.sum(_popcount(x)).astype(jnp.float32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bin_dist(qcodes: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray, *,
             interpret: bool = False) -> jnp.ndarray:
    """(Q, nw) u32 packed queries, (n, nw) u32 packed codes, (Q, B) ids ->
    (Q, B) f32 exact Hamming distances; invalid ids -> +inf."""
    Q, nw = qcodes.shape
    assert codes.shape[1] == nw, (codes.shape, nw)
    B = ids.shape[1]
    assert ids.shape[0] == Q
    safe_ids = jnp.maximum(ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, B),
        in_specs=[
            pl.BlockSpec((1, nw), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, nw), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        _dist_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, B), jnp.float32),
        interpret=interpret,
    )(safe_ids, qcodes, codes)
    return jnp.where(ids >= 0, out, jnp.inf)


# ------------------------------------------------------- fused beam expand
def _make_expand_kernel(C: int, T: int, W: int):
    def kernel(idx_ref, q_ref, code_ref, od_ref, oi_ref, ob_ref,
               ot_ref, acc_ref):
        i, j = pl.program_id(0), pl.program_id(1)
        x = jnp.bitwise_xor(q_ref[...], code_ref[...])   # (1, nw) u32
        acc_ref[0, j] = jnp.sum(_popcount(x)).astype(jnp.float32)

        @pl.when(j == C - 1)
        def _():
            _finalize(i, idx_ref, acc_ref, od_ref, oi_ref, ob_ref, ot_ref,
                      T=T, W=W)
    return kernel


@functools.partial(jax.jit, static_argnames=("L", "n_beam", "interpret"))
def fused_expand_bin(qcodes: jnp.ndarray, codes: jnp.ndarray,
                     ids: jnp.ndarray, *, L: int, n_beam: int = 1,
                     interpret: bool = False):
    """bin twin of fused_expand_pq4: (Q, nw) u32 packed queries, (n, nw)
    u32 packed codes, (Q, C) ids -> sorted candidate block (dists (Q, T)
    ascending, ids (Q, T), bests (Q, n_beam), tie counts (Q, n_beam));
    T = min(L, C). ids < 0 are clamped for the DMA and come back (+inf, -1)."""
    Q, nw = qcodes.shape
    C = ids.shape[1]
    assert codes.shape[1] == nw, (codes.shape, nw)
    assert ids.shape[0] == Q and C % n_beam == 0, (ids.shape, n_beam)
    T = min(L, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, C),
        in_specs=[
            pl.BlockSpec((1, nw), lambda i, j, idx_ref: (i, 0)),
            # raw ids in prefetch (epilogue masks on sign); DMA clamp in
            # the index map — same discipline as traverse_step
            pl.BlockSpec((1, nw),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i, j], 0), 0)),
        ],
        out_specs=_out_specs(T, n_beam),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
    )
    return pl.pallas_call(
        _make_expand_kernel(C, T, n_beam),
        grid_spec=grid_spec,
        out_shape=_out_shapes(Q, T, n_beam),
        interpret=interpret,
    )(ids, qcodes, codes)


# ---------------------------------------------------------------- IVF scan
def _make_scan_kernel(L: int):
    def _kernel(pids_ref, q_ref, codes_ref, ids_ref, od_ref, oi_ref):
        q = q_ref[0]                                     # (nw,) u32
        codes = codes_ref[0]                             # (max_len, nw) u32
        ids = ids_ref[0]                                 # (max_len,)
        x = jnp.bitwise_xor(codes, q[None, :])
        d = jnp.sum(_popcount(x), axis=-1).astype(jnp.float32)
        d = jnp.where(ids >= 0, d, jnp.inf)
        neg, pos = jax.lax.top_k(-d, L)
        od_ref[0, 0] = -neg
        oi_ref[0, 0] = jnp.where(jnp.isfinite(neg), ids[pos], -1)
    return _kernel


@functools.partial(jax.jit, static_argnames=("L", "interpret"))
def bin_ivf_scan(qcodes: jnp.ndarray, list_codes: jnp.ndarray,
                 list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *,
                 L: int, interpret: bool = False):
    """Scan probed inverted lists of packed sign codes (bin ivf_scan twin).

    qcodes:     (Q, nw) uint32 packed query signs
    list_codes: (nlist, max_len, nw) uint32 packed codes
    list_ids:   (nlist, max_len) i32, -1 padding
    probe_ids:  (Q, P) i32
    Returns (dists (Q, P, L) ascending, ids (Q, P, L), -1 padding).
    """
    Q, nw = qcodes.shape
    P = probe_ids.shape[1]
    nlist, max_len, nw2 = list_codes.shape
    assert nw2 == nw, (nw2, nw)
    assert list_ids.shape == (nlist, max_len)
    assert L <= max_len, (L, max_len)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, nw), lambda i, j, pids: (i, 0)),
            pl.BlockSpec((1, max_len, nw), lambda i, j, pids: (pids[i, j], 0, 0)),
            pl.BlockSpec((1, max_len), lambda i, j, pids: (pids[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
        ],
    )
    return pl.pallas_call(
        _make_scan_kernel(L),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q, P, L), jnp.float32),
                   jax.ShapeDtypeStruct((Q, P, L), jnp.int32)],
        interpret=interpret,
    )(probe_ids, qcodes, list_codes, list_ids)
