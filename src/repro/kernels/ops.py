"""Public jit'd wrappers over the Pallas kernels.

Handles: lane-width padding (d -> multiple of 128, the H3 alignment
analogue), tile padding of Q/B, interpret-mode auto-detection (CPU backend
runs kernels in interpret mode for validation; real TPU compiles Mosaic),
and masking of CSR -1 padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import batch_dist as _bd
from repro.kernels import bin_hamming as _bh
from repro.kernels import gather_dist as _gd
from repro.kernels import ivf_scan as _iv
from repro.kernels import pq4_scan as _p4
from repro.kernels import pq_adc as _pq
from repro.kernels import traverse_step as _ts

LANE = 128


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_dim(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def batch_dist(q: jnp.ndarray, x: jnp.ndarray, *, metric: str = "l2",
               tq: int = 128, tb: int = 128) -> jnp.ndarray:
    """(Q, d) x (B, d) -> (Q, B); any shapes, padding handled here."""
    Q, B = q.shape[0], x.shape[0]
    qp = _pad_dim(_pad_dim(q, 1, LANE), 0, tq)
    xp = _pad_dim(_pad_dim(x, 1, LANE), 0, tb)
    out = _bd.batch_dist(qp, xp, metric=metric, tq=tq, tb=tb,
                         interpret=_on_cpu())
    return out[:Q, :B]


def gather_dist(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray, *,
                metric: str = "l2") -> jnp.ndarray:
    """(Q, d), (n, d), (Q, M) -> (Q, M); -1 ids produce +inf."""
    qp = _pad_dim(q, 1, LANE)
    dbp = _pad_dim(db, 1, LANE)
    return _gd.gather_dist(qp, dbp, ids, metric=metric, interpret=_on_cpu())


def pq_adc(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray
           ) -> jnp.ndarray:
    """(Q, m, K), (n, m) u8, (Q, B) -> (Q, B); -1 ids produce +inf."""
    return _pq.pq_adc(lut, codes, ids, interpret=_on_cpu())


def pq4_adc(lut: jnp.ndarray, packed: jnp.ndarray, ids: jnp.ndarray
            ) -> jnp.ndarray:
    """(Q, m, 16), (n, m//2) u8 nibble-packed, (Q, B) -> (Q, B); -1 -> +inf."""
    return _p4.pq4_adc(lut, packed, ids, interpret=_on_cpu())


def bin_dist(qcodes: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray
             ) -> jnp.ndarray:
    """(Q, nw) u32 packed queries, (n, nw) u32 packed codes, (Q, B) ->
    (Q, B) exact Hamming; -1 ids produce +inf. No lane padding: the packed
    word axis is tiny (d=128 -> nw=4) and the kernel reduces it wholesale."""
    return _bh.bin_dist(qcodes, codes, ids, interpret=_on_cpu())


def sq_gather_dist(q: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, ids: jnp.ndarray, *,
                   metric: str = "l2") -> jnp.ndarray:
    """Fused SQ gather+dequant+distance: (Q, d), (n, d) u8, (d,), (d,),
    (Q, M) -> (Q, M); -1 ids produce +inf. Padding keeps the dequant exact:
    padded columns get scale=0/zero=0 so they dequantize to 0, matching the
    zero-padded query columns."""
    qp = _pad_dim(q, 1, LANE)
    cp = _pad_dim(codes, 1, LANE)
    sp = _pad_dim(scale.reshape(1, -1), 1, LANE)
    zp = _pad_dim(zero.reshape(1, -1), 1, LANE)
    return _gd.sq_gather_dist(qp, cp, sp, zp, ids, metric=metric,
                              interpret=_on_cpu())


def fused_expand(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray, *,
                 metric: str = "l2", L: int, n_beam: int = 1):
    """Fused beam-expansion step over full vectors (DESIGN.md §2):
    (Q, d), (n, d), (Q, C) ids -> (sorted dists (Q, T), ids (Q, T),
    per-expansion bests (Q, n_beam)) with T = min(L, C); -1 ids -> +inf.
    On real hardware keep T a power of two (in-kernel sort lowers via a
    bitonic network, as with ivf_scan's top_k)."""
    qp = _pad_dim(q, 1, LANE)
    dbp = _pad_dim(db, 1, LANE)
    return _ts.fused_expand(qp, dbp, ids, metric=metric, L=L,
                            n_beam=n_beam, interpret=_on_cpu())


def fused_expand_sq(q: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                    zero: jnp.ndarray, ids: jnp.ndarray, *,
                    metric: str = "l2", L: int, n_beam: int = 1):
    """SQ twin of fused_expand; same zero-exact padding as sq_gather_dist
    (padded columns dequantize to 0, matching zero-padded query columns)."""
    qp = _pad_dim(q, 1, LANE)
    cp = _pad_dim(codes, 1, LANE)
    sp = _pad_dim(scale.reshape(1, -1), 1, LANE)
    zp = _pad_dim(zero.reshape(1, -1), 1, LANE)
    return _ts.fused_expand_sq(qp, cp, sp, zp, ids, metric=metric, L=L,
                               n_beam=n_beam, interpret=_on_cpu())


def fused_expand_pq(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray,
                    *, L: int, n_beam: int = 1):
    """PQ-ADC twin of fused_expand: (Q, m, K) luts, (n, m) u8 codes."""
    return _ts.fused_expand_pq(lut, codes, ids, L=L, n_beam=n_beam,
                               interpret=_on_cpu())


def fused_expand_pq4(lut: jnp.ndarray, packed: jnp.ndarray,
                     ids: jnp.ndarray, *, L: int, n_beam: int = 1):
    """PQ4 twin: (Q, m, 16) luts, (n, m//2) nibble-packed u8 codes."""
    return _ts.fused_expand_pq4(lut, packed, ids, L=L, n_beam=n_beam,
                                interpret=_on_cpu())


def fused_expand_bin(qcodes: jnp.ndarray, codes: jnp.ndarray,
                     ids: jnp.ndarray, *, L: int, n_beam: int = 1):
    """bin twin: (Q, nw) u32 packed queries, (n, nw) u32 packed codes."""
    return _bh.fused_expand_bin(qcodes, codes, ids, L=L, n_beam=n_beam,
                                interpret=_on_cpu())


def ivf_scan(luts: jnp.ndarray, list_codes: jnp.ndarray,
             list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *, L: int):
    """(Q, Pl, m, K) luts (Pl in {1, P}), padded lists, (Q, P) probes ->
    per-list top-L (dists, ids), each (Q, P, L'). L is clamped to the
    padded list length; on real hardware it is also rounded up to a power
    of two (Mosaic lowers the in-kernel top_k via bitonic sort), so L' may
    exceed the request — callers merge/trim downstream and extra slots are
    just more (possibly +inf) candidates."""
    interp = _on_cpu()
    L = min(L, list_ids.shape[1])
    if not interp:
        L = min(1 << (L - 1).bit_length(), list_ids.shape[1])
    return _iv.ivf_scan(luts, list_codes, list_ids, probe_ids, L=L,
                        interpret=interp)


def pq4_ivf_scan(luts: jnp.ndarray, list_codes: jnp.ndarray,
                 list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *, L: int):
    """pq4 twin of ivf_scan: (Q, Pl, m, 16) luts, (nlist, max_len, m//2)
    nibble-packed list codes. Same L clamping/rounding policy."""
    interp = _on_cpu()
    L = min(L, list_ids.shape[1])
    if not interp:
        L = min(1 << (L - 1).bit_length(), list_ids.shape[1])
    return _p4.pq4_ivf_scan(luts, list_codes, list_ids, probe_ids, L=L,
                            interpret=interp)


def bin_ivf_scan(qcodes: jnp.ndarray, list_codes: jnp.ndarray,
                 list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *, L: int):
    """bin twin of ivf_scan: (Q, nw) u32 packed queries, (nlist, max_len,
    nw) u32 packed list codes. Same L clamping/rounding policy."""
    interp = _on_cpu()
    L = min(L, list_ids.shape[1])
    if not interp:
        L = min(1 << (L - 1).bit_length(), list_ids.shape[1])
    return _bh.bin_ivf_scan(qcodes, list_codes, list_ids, probe_ids, L=L,
                            interpret=interp)
