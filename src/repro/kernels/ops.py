"""Public jit'd wrappers over the Pallas kernels.

Handles: lane-width padding (d -> multiple of 128, the H3 alignment
analogue), tile padding of Q/B, interpret-mode auto-detection (CPU backend
runs kernels in interpret mode for validation; real TPU compiles Mosaic),
and masking of CSR -1 padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import batch_dist as _bd
from repro.kernels import gather_dist as _gd
from repro.kernels import pq_adc as _pq

LANE = 128


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_dim(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def batch_dist(q: jnp.ndarray, x: jnp.ndarray, *, metric: str = "l2",
               tq: int = 128, tb: int = 128) -> jnp.ndarray:
    """(Q, d) x (B, d) -> (Q, B); any shapes, padding handled here."""
    Q, B = q.shape[0], x.shape[0]
    qp = _pad_dim(_pad_dim(q, 1, LANE), 0, tq)
    xp = _pad_dim(_pad_dim(x, 1, LANE), 0, tb)
    out = _bd.batch_dist(qp, xp, metric=metric, tq=tq, tb=tb,
                         interpret=_on_cpu())
    return out[:Q, :B]


def gather_dist(q: jnp.ndarray, db: jnp.ndarray, ids: jnp.ndarray, *,
                metric: str = "l2") -> jnp.ndarray:
    """(Q, d), (n, d), (Q, M) -> (Q, M); -1 ids produce +inf."""
    qp = _pad_dim(q, 1, LANE)
    dbp = _pad_dim(db, 1, LANE)
    return _gd.gather_dist(qp, dbp, ids, metric=metric, interpret=_on_cpu())


def pq_adc(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray
           ) -> jnp.ndarray:
    """(Q, m, K), (n, m) u8, (Q, B) -> (Q, B); -1 ids produce +inf."""
    return _pq.pq_adc(lut, codes, ids, interpret=_on_cpu())
