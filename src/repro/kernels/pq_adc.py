"""pq_adc — PQ asymmetric-distance kernel (paper A4, ScaNN-style on MXU).

ADC distance of a database code against a query lookup table:
    dist[q, b] = sum_j LUT[q, j, code[ids[q, b], j]]

x86 libraries implement the LUT gather with AVX shuffle bytes; the TPU has
no register shuffle, but the MXU gives the native equivalent: one-hot expand
the (m,) code row and contract it against the (m, K) LUT — a (1, m*K) x
(m*K, 1) dot, i.e. the gather becomes a matmul, which is exactly how the MXU
wants to consume it. Codes rows are fetched by the same scalar-prefetch
gather mechanism as gather_dist (H2), so code reads for step i+1 overlap
step i's arithmetic.

Grid: (Q, B); blocks: LUT (1, m, K) by q, codes (1, m) by ids[q, b].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, lut_ref, code_ref, o_ref):
    lut = lut_ref[...].astype(jnp.float32)        # (1, m, K)
    code = code_ref[...].astype(jnp.int32)        # (1, m)
    m, K = lut.shape[1], lut.shape[2]
    onehot = (code[0, :, None] == jax.lax.broadcasted_iota(jnp.int32, (m, K), 1)
              ).astype(jnp.float32)               # (m, K)
    o_ref[...] = jnp.sum(lut[0] * onehot).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq_adc(lut: jnp.ndarray, codes: jnp.ndarray, ids: jnp.ndarray, *,
           interpret: bool = False) -> jnp.ndarray:
    """(Q, m, K) luts, (n, m) uint8 codes, (Q, B) int32 ids -> (Q, B) f32."""
    Q, m, K = lut.shape
    B = ids.shape[1]
    assert ids.shape[0] == Q
    safe_ids = jnp.maximum(ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, B),
        in_specs=[
            pl.BlockSpec((1, m, K), lambda i, j, idx_ref: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, B), jnp.float32),
        interpret=interpret,
    )(safe_ids, lut, codes)
    return jnp.where(ids >= 0, out, jnp.inf)
