"""pq4_scan — 4-bit fast-scan PQ ADC kernels (DESIGN.md §13).

x86 fast-scan (and its ARM port, the "ARM 4-bit PQ" line of work) shrinks
PQ sub-codebooks to 16 centroids so the whole (m, 16) lookup table fits in
SIMD registers and the LUT gather becomes an in-register byte shuffle. The
TPU analogue implemented here: the table is 16x smaller than 8-bit PQ's
(m, 256), so it stays RESIDENT IN VMEM across the whole scan (no per-step
LUT traffic), codes arrive nibble-packed (two per byte — half the DMA
bytes of pq_adc), and the gather is the same one-hot MXU contraction with a
16-wide, rather than 256-wide, contraction axis.

Two kernels share the nibble-unpack + one-hot idiom:

  pq4_adc      — graph-path gather ADC, grid (Q, B): the packed code row of
                 neighbor ids[q, b] is fetched by scalar-prefetch (H2, same
                 mechanism as pq_adc/gather_dist) and scored against query
                 q's VMEM-resident LUT.
  pq4_ivf_scan — IVF list scan + per-list partial top-L, grid (Q, P): the
                 pq4 twin of ivf_scan (same prefetch-driven list DMA, same
                 in-kernel top-L partial reduction), consuming packed
                 (nlist, max_len, m//2) list codes.

Nibble layout (core/quantize.py: pq4_pack): byte j = subspace 2j in the low
nibble, 2j+1 in the high nibble; the kernels unpack with a mask/shift pair
and interleave back to (m,) code rows.

NOTE: in-kernel top_k is interpret-exact on CPU; Mosaic lowers it via
bitonic sort on real TPU — keep L a power of two there (ops.py rounds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

K4 = 16  # centroids per 4-bit sub-codebook


def _unpack_rows(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., m//2) i32 packed bytes -> (..., m) i32 codes in [0, 16)."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    return jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (2 * packed.shape[-1],))


# ------------------------------------------------------------ graph gather
def _adc_kernel(idx_ref, lut_ref, code_ref, o_ref):
    lut = lut_ref[...].astype(jnp.float32)        # (1, m, 16)
    packed = code_ref[...].astype(jnp.int32)      # (1, m//2)
    m, K = lut.shape[1], lut.shape[2]
    code = _unpack_rows(packed[0])                # (m,)
    onehot = (code[:, None] == jax.lax.broadcasted_iota(jnp.int32, (m, K), 1)
              ).astype(jnp.float32)               # (m, 16)
    o_ref[...] = jnp.sum(lut[0] * onehot).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq4_adc(lut: jnp.ndarray, packed: jnp.ndarray, ids: jnp.ndarray, *,
            interpret: bool = False) -> jnp.ndarray:
    """(Q, m, 16) luts, (n, m//2) u8 packed codes, (Q, B) ids -> (Q, B) f32."""
    Q, m, K = lut.shape
    assert K == K4, K
    mh = packed.shape[1]
    assert mh * 2 == m, (mh, m)
    B = ids.shape[1]
    assert ids.shape[0] == Q
    safe_ids = jnp.maximum(ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, B),
        in_specs=[
            # LUT block depends only on q: it is DMA'd once per query row
            # and stays VMEM-resident across the B inner steps
            pl.BlockSpec((1, m, K), lambda i, j, idx_ref: (i, 0, 0)),
            pl.BlockSpec((1, mh), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        _adc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, B), jnp.float32),
        interpret=interpret,
    )(safe_ids, lut, packed)
    return jnp.where(ids >= 0, out, jnp.inf)


# ---------------------------------------------------------------- IVF scan
def _make_scan_kernel(L: int):
    def _kernel(pids_ref, lut_ref, codes_ref, ids_ref, od_ref, oi_ref):
        lut = lut_ref[0, 0].astype(jnp.float32)          # (m, 16)
        packed = codes_ref[0].astype(jnp.int32)          # (max_len, m//2)
        ids = ids_ref[0]                                 # (max_len,)
        m, K = lut.shape
        max_len = packed.shape[0]
        codes = _unpack_rows(packed)                     # (max_len, m)
        # gather-as-matmul: onehot (max_len, m*16) @ lut (m*16, 1) — the
        # contraction axis is 16x shorter than ivf_scan's, same MXU idiom
        onehot = (codes[:, :, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (max_len, m, K), 2)
                  ).astype(jnp.float32)
        d = jax.lax.dot_general(
            onehot.reshape(max_len, m * K), lut.reshape(m * K, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]    # (max_len,)
        d = jnp.where(ids >= 0, d, jnp.inf)
        neg, pos = jax.lax.top_k(-d, L)
        od_ref[0, 0] = -neg
        oi_ref[0, 0] = jnp.where(jnp.isfinite(neg), ids[pos], -1)
    return _kernel


@functools.partial(jax.jit, static_argnames=("L", "interpret"))
def pq4_ivf_scan(luts: jnp.ndarray, list_codes: jnp.ndarray,
                 list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *,
                 L: int, interpret: bool = False):
    """Scan probed inverted lists of nibble-packed codes (pq4 ivf_scan twin).

    luts:       (Q, Pl, m, 16) f32, Pl in {1, P} (see ivf_scan)
    list_codes: (nlist, max_len, m//2) uint8 packed codes
    list_ids:   (nlist, max_len) i32, -1 padding
    probe_ids:  (Q, P) i32
    Returns (dists (Q, P, L) ascending, ids (Q, P, L), -1 padding).
    """
    Q, Pl, m, K = luts.shape
    assert K == K4, K
    P = probe_ids.shape[1]
    nlist, max_len, mh = list_codes.shape
    assert mh * 2 == m, (mh, m)
    assert Pl in (1, P), (Pl, P)
    assert list_ids.shape == (nlist, max_len)
    assert L <= max_len, (L, max_len)
    lut_j = (lambda i, j, pids: (i, j, 0, 0)) if Pl == P else \
        (lambda i, j, pids: (i, 0, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, 1, m, K), lut_j),
            pl.BlockSpec((1, max_len, mh), lambda i, j, pids: (pids[i, j], 0, 0)),
            pl.BlockSpec((1, max_len), lambda i, j, pids: (pids[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
        ],
    )
    return pl.pallas_call(
        _make_scan_kernel(L),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q, P, L), jnp.float32),
                   jax.ShapeDtypeStruct((Q, P, L), jnp.int32)],
        interpret=interpret,
    )(probe_ids, luts, list_codes, list_ids)
