"""ivf_scan — fused IVF-PQ list scan + partial top-L kernel (DESIGN.md §4).

One grid step scans one (query, probed-list) pair: the ADC distances of every
code in the list are computed against that pair's (m, K) lookup table, and
the list is reduced to its top-L candidates *before* leaving the kernel, so
output traffic per step is O(L) instead of O(max_len) — the partial
reduction ScaNN-style CPU scanners do per SIMD register block, lifted to a
whole inverted list per step.

The ADC gather itself is the same MXU idiom as pq_adc, batched over the
list: one-hot expand the (max_len, m) code block and contract it against the
flattened LUT — a (max_len, m*K) x (m*K, 1) matmul, i.e. the paper's 1-to-B
H1 batching in its 2-D lift (same move as batch_dist, with the list playing
the role of the neighbor batch).

Prefetch (H2 analogue): the codes/ids blocks for step (q, p) are the rows of
`list_codes`/`list_ids` selected by the scalar-prefetched `probe_ids[q, p]`,
so the pipeline engine DMAs list p+1 while list p is being scanned — the
software-prefetch trick of the paper's Fig. 5 applied to inverted lists.

Grid: (Q, P). Blocks: LUT (1, 1, m, K) by (q, p); codes (1, max_len, m) and
ids (1, max_len) by probe_ids[q, p]; outputs (1, 1, L) by (q, p).

NOTE: the in-kernel reduction uses jax.lax.top_k, which interpret mode (the
CPU validation path, see ops.py) executes directly; on real TPU hardware
Mosaic lowers it via a bitonic sort — keep L a power of two there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(L: int):
    def _kernel(pids_ref, lut_ref, codes_ref, ids_ref, od_ref, oi_ref):
        lut = lut_ref[0, 0].astype(jnp.float32)          # (m, K)
        codes = codes_ref[0].astype(jnp.int32)           # (max_len, m)
        ids = ids_ref[0]                                 # (max_len,)
        max_len, m = codes.shape
        K = lut.shape[1]
        # gather-as-matmul: onehot (max_len, m*K) @ lut (m*K, 1) on the MXU
        onehot = (codes[:, :, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (max_len, m, K), 2)
                  ).astype(jnp.float32)
        d = jax.lax.dot_general(
            onehot.reshape(max_len, m * K), lut.reshape(m * K, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]    # (max_len,)
        d = jnp.where(ids >= 0, d, jnp.inf)
        # partial reduction: this list's top-L leaves the kernel, not max_len
        neg, pos = jax.lax.top_k(-d, L)
        od_ref[0, 0] = -neg
        oi_ref[0, 0] = jnp.where(jnp.isfinite(neg), ids[pos], -1)
    return _kernel


@functools.partial(jax.jit, static_argnames=("L", "interpret"))
def ivf_scan(luts: jnp.ndarray, list_codes: jnp.ndarray,
             list_ids: jnp.ndarray, probe_ids: jnp.ndarray, *,
             L: int, interpret: bool = False):
    """Scan probed inverted lists, returning per-list top-L candidates.

    luts:       (Q, Pl, m, K) f32 ADC tables; Pl is the probe count P, or 1
                when the table is probe-independent (non-residual, or ip
                with the centroid bias handled outside) — the kernel then
                re-reads the single block instead of materializing P copies
    list_codes: (nlist, max_len, m) uint8 PQ codes, padded rows arbitrary
    list_ids:   (nlist, max_len) i32 database ids, -1 padding
    probe_ids:  (Q, P) i32 probed cluster ids
    Returns (dists (Q, P, L) f32 ascending, ids (Q, P, L) i32, -1 padding).
    """
    Q, Pl, m, K = luts.shape
    P = probe_ids.shape[1]
    nlist, max_len = list_ids.shape
    assert Pl in (1, P), (Pl, P)
    assert probe_ids.shape == (Q, P) and list_codes.shape == (nlist, max_len, m)
    assert L <= max_len, (L, max_len)
    lut_j = (lambda i, j, pids: (i, j, 0, 0)) if Pl == P else \
        (lambda i, j, pids: (i, 0, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, 1, m, K), lut_j),
            pl.BlockSpec((1, max_len, m), lambda i, j, pids: (pids[i, j], 0, 0)),
            pl.BlockSpec((1, max_len), lambda i, j, pids: (pids[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
            pl.BlockSpec((1, 1, L), lambda i, j, pids: (i, j, 0)),
        ],
    )
    return pl.pallas_call(
        _make_kernel(L),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q, P, L), jnp.float32),
                   jax.ShapeDtypeStruct((Q, P, L), jnp.int32)],
        interpret=interpret,
    )(probe_ids, luts, list_codes, list_ids)
