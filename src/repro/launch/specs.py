"""Per-(arch x shape) dry-run cell builders.

For each of the 40 assigned cells this module produces:
  step_fn      the function to lower (train_step / serve_step / prefill /
               retrieval — per the shape's kind),
  arg_specs    ShapeDtypeStruct stand-ins for every input (weak-type
               correct, shardable, NO device allocation),
  in_shardings matching NamedShardings from sharding/rules.py.

The returned closure is what launch/dryrun.py lowers + compiles on the
production meshes. Optimizer choice: AdamW for <= 20B-param models,
Adafactor for the MoE giants (factored second moment — the difference
between fitting and not fitting v5e HBM; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as cfg_registry
from repro.sharding import rules
from repro.train.optimizer import OptConfig, opt_init, opt_update

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


class Cell(NamedTuple):
    arch: str
    shape: str
    kind: str                    # train | prefill | decode | serve | retrieval
    step_fn: Callable
    args: Tuple                  # ShapeDtypeStructs
    in_shardings: Tuple
    meta: Dict[str, Any]         # model-flops accounting inputs etc.


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


# =========================================================== LM cells ======
LM_SHAPE_PARAMS = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _lm_param_count(cfg) -> float:
    """Total and active parameter counts (for MODEL_FLOPS = 6*N*D)."""
    d, hd = cfg.d_model, cfg.hd
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * d
    if cfg.moe is not None:
        m = cfg.moe
        per_exp = (3 if m.gated else 2) * d * m.d_ff_expert
        moe_total = m.n_experts * per_exp
        moe_active = m.top_k * per_exp
        shared = m.n_shared_experts * per_exp
        total = cfg.n_layers * (attn + moe_total + shared)
        active = cfg.n_layers * (attn + moe_active + shared)
    else:
        mlp = (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
        total = cfg.n_layers * (attn + mlp)
        active = total
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def _lm_cell(arch: str, shape: str, mesh: Mesh, depth=None,
             unroll=False, opts=None) -> Cell:
    import dataclasses

    from repro.models import transformer as T

    opts = opts or {}
    mod = cfg_registry.get(arch)
    cfg = mod.full_config()
    if depth is not None or unroll:
        cfg = dataclasses.replace(
            cfg, n_layers=depth or cfg.n_layers, unroll_layers=unroll)
    dp = rules.dp_axes(mesh)
    moe_d_sharded = False
    if opts.get("moe_sm") and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, ep_axis="data", tp_axis="model", token_axes=dp,
            use_shardmap=True, ep_size=mesh.shape["data"],
            tp_size=mesh.shape["model"]))
        moe_d_sharded = True
    elif opts.get("moe_ep") and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, ep_axis="data", tp_axis="model", token_axes=dp))
    if opts.get("lm_loss"):
        cfg = dataclasses.replace(cfg, loss_vocab_axis="model",
                                  loss_batch_axes=dp,
                                  loss_vocab_shards=mesh.shape["model"])
    if opts.get("remat_dots"):
        cfg = dataclasses.replace(cfg, remat_policy=opts["remat_dots"]
                                  if isinstance(opts["remat_dots"], str)
                                  else "dots")
    sp = LM_SHAPE_PARAMS[shape]
    B, S = sp["batch"], sp["seq"]
    kind = sp["kind"]

    params_s = _eval_shapes(
        functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))
    p_sh = rules.tree_param_shardings(params_s, mesh, "lm",
                                      moe_d_sharded=moe_d_sharded)
    n_total, n_active = _lm_param_count(cfg)
    opt_cfg = OptConfig(kind="adafactor" if cfg.moe is not None else "adamw")

    if kind == "train":
        opt_s = _eval_shapes(
            functools.partial(opt_init, cfg=opt_cfg), params_s)
        o_sh = _opt_shardings(opt_s, p_sh, mesh)
        batch = {"tokens": _sds((B, S + 1), I32)}
        b_sh = rules.tree_batch_shardings(batch, mesh, "lm")

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                T.loss_fn, has_aux=True)(params, batch, cfg)
            new_p, new_s, gnorm = opt_update(grads, opt_state, params, opt_cfg)
            return new_p, new_s, loss

        return Cell(arch, shape, kind, step, (params_s, opt_s, batch),
                    (p_sh, o_sh, b_sh),
                    dict(model_flops=6.0 * n_active * B * S, tokens=B * S,
                         n_total=n_total, n_active=n_active))

    if kind == "prefill":
        tokens = _sds((B, S), I32)
        t_sh = rules.tree_batch_shardings(tokens, mesh, "lm")

        def step(params, tokens):
            return T.prefill(params, tokens, cfg)

        return Cell(arch, shape, kind, step, (params_s, tokens),
                    (p_sh, t_sh),
                    dict(model_flops=2.0 * n_active * B * S, tokens=B * S,
                         n_total=n_total, n_active=n_active))

    # decode
    cache_s = _eval_shapes(
        functools.partial(T.init_cache, cfg, B, S), )
    c_sh = rules.lm_cache_shardings(cache_s, mesh)
    tokens = _sds((B, 1), I32)
    t_sh = rules.tree_batch_shardings(tokens, mesh, "lm")

    def step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg)

    # decode flops: 2*N_active per token + cache read bytes dominate
    return Cell(arch, shape, "decode", step, (params_s, cache_s, tokens),
                (p_sh, c_sh, t_sh),
                dict(model_flops=2.0 * n_active * B, tokens=B,
                     n_total=n_total, n_active=n_active,
                     cache_bytes=2 * cfg.n_layers * B * S
                     * cfg.n_kv_heads * cfg.hd * 2))


def _opt_shardings(opt_s, p_sh, mesh):
    """ZeRO-1 shardings for optimizer moments: param spec (rank-adapted for
    Adafactor's factored vr/vc) + DP over the largest replicated dim.
    Moment trees have the param tree as a prefix."""
    def fill(ps, subtree):
        pspec = list(ps.spec) if hasattr(ps, "spec") else []

        def leaf(path, x):
            key = str(getattr(path[-1], "key", "")) if path else ""
            r = len(x.shape)
            parts = pspec + [None] * (r + 1 - len(pspec))
            if key == "vr":          # param.shape[:-1] -> drop last spec dim
                spec = P(*parts[:r])
            elif key == "vc":        # param.shape[:-2] + (param.shape[-1],)
                spec = P(*(parts[:r - 1] + [parts[r]]))
            else:                    # v / m: same shape as param
                spec = P(*parts[:r])
            spec = rules.zero1_state_spec(spec, x.shape, mesh)
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(leaf, subtree)

    def map_state(state):
        out = {}
        for k, v in state.items():
            if k == "count":
                out[k] = NamedSharding(mesh, P())
            elif k in ("m", "v"):
                out[k] = jax.tree.map(
                    lambda ps, sub: fill(ps, sub), p_sh, v,
                    is_leaf=lambda x: isinstance(x, NamedSharding))
            else:
                out[k] = jax.tree.map(lambda _: NamedSharding(mesh, P()), v)
        return out
    return map_state(opt_s)


# ========================================================== GNN cells ======
def _gnn_cell(arch: str, shape: str, mesh: Mesh, depth=None,
              unroll=False, opts=None) -> Cell:
    import dataclasses

    from repro.configs.dimenet import SHAPE_PARAMS, TRIPLET_CAP
    from repro.models import dimenet as D

    opts = opts or {}
    mod = cfg_registry.get(arch)
    cfg = mod.full_config(shape)
    if depth is not None or unroll:
        cfg = dataclasses.replace(
            cfg, n_blocks=depth or cfg.n_blocks, unroll_blocks=unroll)
    if opts.get("gnn_remat"):
        cfg = dataclasses.replace(cfg, remat=True)
    sp = SHAPE_PARAMS[shape]

    if shape == "minibatch_lg":
        b = sp["batch_nodes"]
        f1, f2 = sp["fanouts"]
        N = b + b * f1 + b * f1 * f2
        E = b * f1 + b * f1 * f2
    elif shape == "molecule":
        N = sp["n_nodes"] * sp["batch"]
        E = sp["n_edges"] * sp["batch"]
    else:
        N, E = sp["n_nodes"], sp["n_edges"]
    T_ = E * TRIPLET_CAP
    n_graphs = sp.get("batch", 1)

    batch = {
        "feats": _sds((N, sp["d_feat"]), F32),
        "pos": _sds((N, 3), F32),
        "edge_src": _sds((E,), I32), "edge_dst": _sds((E,), I32),
        "trip_kj": _sds((T_,), I32), "trip_ji": _sds((T_,), I32),
    }
    if cfg.task == "graph_reg":
        batch["node_graph"] = _sds((N,), I32)
        batch["targets"] = _sds((n_graphs,), F32)
    else:
        batch["labels"] = _sds((N,), I32)

    params_s = _eval_shapes(
        functools.partial(D.init_params, cfg), jax.random.PRNGKey(0))
    p_sh = rules.tree_param_shardings(params_s, mesh, "gnn")
    b_sh = rules.tree_batch_shardings(batch, mesh, "gnn",
                                      gnn_shard_all=bool(opts.get("gnn_shard_all")))
    opt_cfg = OptConfig(kind="adamw")
    opt_s = _eval_shapes(functools.partial(opt_init, cfg=opt_cfg), params_s)
    o_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_s)

    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            D.loss_fn, has_aux=True)(params, batch, cfg, n_graphs)
        new_p, new_s, _ = opt_update(grads, opt_state, params, opt_cfg)
        return new_p, new_s, loss

    # message-passing flops: per block, triplet gather T*nb + edge GEMMs
    H = cfg.d_hidden
    mf = cfg.n_blocks * (2.0 * E * H * H * 4 + 2.0 * T_ * cfg.n_bilinear) \
        + 2.0 * N * sp["d_feat"] * H
    return Cell(arch, shape, "train", step, (params_s, opt_s, batch),
                (p_sh, o_sh, b_sh), dict(model_flops=mf, tokens=N))


# ======================================================= recsys cells ======
RECSYS_SHAPE_PARAMS = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}


def _recsys_batch_specs(cfg, B: int, kind: str) -> dict:
    if cfg.kind in ("fm", "deepfm"):
        b = {"sparse_ids": _sds((B, cfg.n_sparse), I32)}
        if kind == "train":
            b["label"] = _sds((B,), F32)
    elif cfg.kind == "bst":
        b = {"hist": _sds((B, cfg.seq_len), I32),
             "target": _sds((B,), I32)}
        if kind == "train":
            b["label"] = _sds((B,), F32)
    else:  # bert4rec
        b = {"seq": _sds((B, cfg.seq_len), I32)}
        if kind == "train":
            b["labels"] = _sds((B, cfg.seq_len), I32)
        elif kind == "serve":
            b["cand"] = _sds((B,), I32)
    return b


def _recsys_flops(cfg, B: int) -> float:
    if cfg.kind in ("fm", "deepfm"):
        f = 2.0 * B * cfg.n_sparse * cfg.embed_dim
        if cfg.kind == "deepfm":
            dims = (cfg.n_sparse * cfg.embed_dim,) + tuple(cfg.mlp_dims) + (1,)
            f += 2.0 * B * sum(a * b for a, b in zip(dims, dims[1:]))
        return f
    S, Dm = (cfg.seq_len + (1 if cfg.kind == "bst" else 0)), cfg.d_model
    per_block = 2.0 * S * (4 * Dm * Dm) + 2.0 * S * S * Dm * 2 \
        + 2.0 * S * (8 * Dm * Dm)
    f = B * cfg.n_blocks * per_block
    if cfg.kind == "bst":
        dims = (S * Dm,) + tuple(cfg.mlp_dims) + (1,)
        f += 2.0 * B * sum(a * b for a, b in zip(dims, dims[1:]))
    return f


def _recsys_cell(arch: str, shape: str, mesh: Mesh, depth=None,
                 unroll=False, opts=None) -> Cell:
    import dataclasses

    from repro.models import recsys as R

    opts = opts or {}
    mod = cfg_registry.get(arch)
    cfg = mod.full_config()
    if depth is not None or unroll:
        cfg = dataclasses.replace(
            cfg, n_blocks=depth or cfg.n_blocks, unroll_blocks=unroll)
    if opts.get("masked_loss") and cfg.kind == "bert4rec":
        cfg = dataclasses.replace(cfg, masked_positions=40)
    sp = RECSYS_SHAPE_PARAMS[shape]
    B, kind = sp["batch"], sp["kind"]

    params_s = _eval_shapes(
        functools.partial(R.init_params, cfg), jax.random.PRNGKey(0))
    p_sh = rules.tree_param_shardings(params_s, mesh, "recsys")
    batch = _recsys_batch_specs(cfg, B, kind)
    b_sh = rules.tree_batch_shardings(batch, mesh, "recsys")

    if kind == "train":
        opt_cfg = OptConfig(kind="adamw")
        opt_s = _eval_shapes(functools.partial(opt_init, cfg=opt_cfg), params_s)
        o_sh = _opt_shardings(opt_s, p_sh, mesh)

        def step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                R.loss_fn, has_aux=True)(params, batch, cfg)
            new_p, new_s, _ = opt_update(grads, opt_state, params, opt_cfg)
            return new_p, new_s, loss

        return Cell(arch, shape, kind, step, (params_s, opt_s, batch),
                    (p_sh, o_sh, b_sh),
                    dict(model_flops=3.0 * _recsys_flops(cfg, B), tokens=B))

    if kind == "serve":
        def step(params, batch):
            return R.serve_step(params, batch, cfg)

        return Cell(arch, shape, kind, step, (params_s, batch), (p_sh, b_sh),
                    dict(model_flops=_recsys_flops(cfg, B), tokens=B))

    # retrieval: the paper's vector-search workload, exact 1-to-B path
    n_cand = sp["n_candidates"]

    if opts.get("retrieval_sharded"):
        def step(params, batch):
            return R.serve_retrieval_shardmap(params, batch, cfg, mesh,
                                              k=100)
    else:
        def step(params, batch):
            return R.serve_retrieval(params, batch, cfg, k=100)

    D_ = cfg.embed_dim if cfg.kind in ("fm", "deepfm") else cfg.d_model
    return Cell(arch, shape, kind, step, (params_s, batch), (p_sh, b_sh),
                dict(model_flops=_recsys_flops(cfg, B)
                     + 2.0 * B * n_cand * D_, tokens=B,
                     n_candidates=n_cand))


# ================================================================ facade ===
# Named optimization variants (EXPERIMENTS.md §Perf). "baseline" is the
# paper-faithful configuration; each variant toggles one hillclimb change.
VARIANTS = {
    "baseline": {},
    "moe_ep": {"moe_ep": True},
    "lm_loss": {"lm_loss": True},
    "lm_opt": {"moe_ep": True, "lm_loss": True, "remat_dots": True},
    "lm_opt_nb": {"moe_ep": True, "lm_loss": True, "remat_dots": "dots_nb"},
    "moe_sm": {"moe_sm": True, "lm_loss": True},
    "moe_sm_dots": {"moe_sm": True, "lm_loss": True, "remat_dots": True},
    "gnn_mem": {"gnn_remat": True, "gnn_shard_all": True},
    "gnn_remat": {"gnn_remat": True},
    "retr_shard": {"retrieval_sharded": True},
    "masked_loss": {"masked_loss": True},
    "opt": {"moe_ep": True, "lm_loss": True, "gnn_remat": True,
            "gnn_shard_all": True, "retrieval_sharded": True,
            "masked_loss": True},
}


def build_cell(arch: str, shape: str, mesh: Mesh, depth=None,
               unroll: bool = False, variant: str = "baseline") -> Cell:
    """depth/unroll: cost-extrapolation variants (launch/dryrun.py) — XLA's
    cost_analysis counts a scan body once, so the dry-run lowers unrolled
    1- and 2-layer variants and extrapolates total = f1 + (L-1)*(f2-f1)."""
    opts = VARIANTS[variant]
    mod = cfg_registry.get(arch)
    fam = mod.FAMILY
    assert shape in mod.SHAPES, (arch, shape, mod.SHAPES)
    if fam == "lm":
        return _lm_cell(arch, shape, mesh, depth, unroll, opts)
    if fam == "gnn":
        return _gnn_cell(arch, shape, mesh, depth, unroll, opts)
    return _recsys_cell(arch, shape, mesh, depth, unroll, opts)


def cell_depth(arch: str) -> int:
    """The layer-loop trip count of the arch's full config (1 = no loop)."""
    mod = cfg_registry.get(arch)
    if mod.FAMILY == "lm":
        return mod.full_config().n_layers
    if mod.FAMILY == "gnn":
        return mod.full_config("full_graph_sm").n_blocks
    cfg = mod.full_config()
    return getattr(cfg, "n_blocks", 1) if cfg.kind in ("bst", "bert4rec") else 1
