import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing import: jax locks the device count at
#   first backend init. Only the dry-run sees 512 placeholder devices.

_DOC = """Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the cell's
step function against the production meshes:

    single-pod : (data=16, model=16)        = 256 chips
    multi-pod  : (pod=2, data=16, model=16) = 512 chips

and record memory_analysis() (proves it fits), cost_analysis() (FLOPs /
bytes for §Roofline) and the per-collective byte counts parsed from the
partitioned HLO (collective term). Artifacts land in
experiments/dryrun/<arch>__<shape>__<mesh>.json — benchmarks/roofline.py
consumes them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro import configs as cfg_registry
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.specs import build_cell

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] token in an HLO result spec."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op result bytes from the (partitioned) HLO module.

    Counts the RESULT size of each collective op once per execution; for
    scan bodies the op appears once in the HLO but runs L times — we scale
    by trip count when the op lives inside a while body annotated with a
    known trip count (conservative: unscaled if unknown, reported raw).
    """
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "fusion" in s.split("=")[-1][:40]:
            continue
        for c in _COLLECTIVES:
            if re.search(rf"= [^=]*\b{re.escape(c)}(-start|-done)?\(", s):
                if c + "-done" in s:     # avoid double count of start/done
                    continue
                lhs = s.split("=")[1] if "=" in s else s
                out[c] += _shape_bytes(lhs.split(c)[0])
                counts[c] += 1
                break
    return {"bytes": out, "ops": counts,
            "total_bytes": sum(out.values())}


def while_trip_counts(hlo_text: str):
    """Best-effort scan trip counts (to scale per-iteration collectives)."""
    trips = re.findall(r"trip_count=(\d+)", hlo_text)
    return [int(t) for t in trips]


def _lower_metrics(arch, shape, mesh, depth, unroll, variant="baseline"):
    """Compile a depth/unroll variant and pull (flops, bytes, coll_bytes)."""
    cell = build_cell(arch, shape, mesh, depth=depth, unroll=unroll,
                      variant=variant)
    with mesh_context(mesh):
        compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings) \
            .lower(*cell.args).compile()
        cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"])}


def extrapolate_cost(arch: str, shape: str, mesh, variant="baseline") -> dict:
    """Loop-trip-corrected per-device cost: XLA counts a scan body once, so
    lower UNROLLED depth-1 and depth-2 variants and extrapolate
        total(L) = f(1) + (L - 1) * (f(2) - f(1)).
    For loop-free archs (deepfm/fm, 1-block bst) one unrolled lowering is
    exact."""
    from repro.launch.specs import cell_depth
    L = cell_depth(arch)
    if L <= 1:
        out = _lower_metrics(arch, shape, mesh, None, True, variant)
        out["method"] = "direct"
        return out
    f1 = _lower_metrics(arch, shape, mesh, 1, True, variant)
    f2 = _lower_metrics(arch, shape, mesh, 2, True, variant)
    out = {k: f1[k] + (L - 1) * max(f2[k] - f1[k], 0.0)
           for k in ("flops", "bytes", "coll_bytes")}
    out["method"] = f"extrapolated(1,2->{L})"
    out["per_layer"] = {k: max(f2[k] - f1[k], 0.0)
                        for k in ("flops", "bytes", "coll_bytes")}
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, save: bool = True,
             variant: str = "baseline") -> dict:
    arch = arch.replace("-", "_").replace(".", "_")   # canonical module name
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, variant=variant)
    with mesh_context(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    trips = while_trip_counts(hlo)
    dt = time.time() - t0

    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost_d = {k: cost.get(k) for k in
              ("flops", "bytes accessed", "transcendentals")} if cost else {}
    try:
        extra = extrapolate_cost(arch, shape, mesh, variant)
    except Exception as e:   # cost model must never fail the dry-run cell
        extra = {"error": repr(e)}
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "variant": variant,
        "kind": cell.kind, "ok": True, "seconds": round(dt, 1),
        "devices": int(len(mesh.devices.reshape(-1))),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "cost_extrapolated": extra,
        "collectives": coll,
        "while_trip_counts": trips,
        "meta": cell.meta,
    }
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        out = ART_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", type=str, default="baseline",
                    help="optimization variant (see launch/specs.VARIANTS)")
    args = ap.parse_args()

    cells = (list(cfg_registry.all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            fname = ART_DIR / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_existing and fname.exists() \
                    and json.loads(fname.read_text()).get("ok"):
                print(f"[skip] {arch} {shape} {mesh_name}")
                continue
            try:
                rec = run_cell(arch, shape, mp, variant=args.variant)
                mem = rec["memory_analysis"]
                print(f"[ok]   {arch:24s} {shape:14s} {mesh_name:10s} "
                      f"{rec['seconds']:6.1f}s "
                      f"args={_gb(mem['argument_bytes'])} "
                      f"temp={_gb(mem['temp_bytes'])} "
                      f"coll={_gb(rec['collectives']['total_bytes'])}")
            except Exception as e:
                failures.append((arch, shape, mesh_name, repr(e)))
                traceback.print_exc()
                print(f"[FAIL] {arch} {shape} {mesh_name}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


def _gb(b):
    return "-" if b is None else f"{b/2**30:.2f}G"


if __name__ == "__main__":
    main()
