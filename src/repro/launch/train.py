"""Training launcher: --arch <id> over the production (or test) mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 20 --batch 8 --seq 128

On this CPU container only --smoke configs actually execute; full configs
are exercised via the dry-run (launch/dryrun.py). On a real TPU fleet the
same entry point runs the full config: the mesh/sharding/trainer paths are
identical (that is the point of the dry-run).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs as reg
from repro.data.pipeline import Prefetcher, ctr_batches, lm_batches, seq_batches
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt", choices=("adamw", "adafactor"), default="adamw")
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_ckpt")
    args = ap.parse_args()

    mod = reg.get(args.arch)
    cfg = mod.smoke_config() if args.smoke else (
        mod.full_config("full_graph_sm") if mod.FAMILY == "gnn"
        else mod.full_config())

    if mod.FAMILY == "lm":
        from repro.models import transformer as M
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        data = Prefetcher(lm_batches(cfg.vocab, args.batch, args.seq))
        lfn = lambda p, b: M.loss_fn(p, b, cfg)
    elif mod.FAMILY == "recsys":
        from repro.models import recsys as M
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        if cfg.kind in ("fm", "deepfm"):
            data = Prefetcher(ctr_batches(cfg.n_sparse, cfg.vocab_per_field,
                                          args.batch))
        else:
            data = Prefetcher(seq_batches(cfg.kind, cfg.n_items, args.batch,
                                          cfg.seq_len))
        lfn = lambda p, b: M.loss_fn(p, b, cfg)
    else:
        from repro.data.pipeline import gnn_minibatches
        from repro.models import dimenet as M
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        data = Prefetcher(gnn_minibatches(
            n_nodes=2000, d_feat=cfg.d_feat, batch_nodes=args.batch,
            fanouts=(5, 3), n_classes=cfg.n_out))
        lfn = lambda p, b: M.loss_fn(p, b, cfg)

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={args.arch} family={mod.FAMILY} params={n_params/1e6:.2f}M")
    trainer = Trainer(lfn, OptConfig(kind=args.opt, lr=args.lr),
                      TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=25,
                                    log_every=5))
    trainer.install_signal_handler()
    out = trainer.fit(params, data, n_steps=args.steps)
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}")


if __name__ == "__main__":
    main()
