"""Production mesh construction (DESIGN.md §6, system-prompt contract).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the device count at first backend init, and tests
must see 1 CPU device while the dry-run sees 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_context(mesh):
    """Version-portable "activate this mesh" context manager.

    jax >= 0.6 activates a mesh for bare-PartitionSpec sharding constraints
    via jax.set_mesh; on 0.4.x the Mesh object itself is the context
    manager (resource-env API). Same scoping semantics either way.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
