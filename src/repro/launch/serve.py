"""Serving launcher: KBest ANNS service or model serve steps.

    # ANNS service over a synthetic corpus
    PYTHONPATH=src python -m repro.launch.serve --mode ann --n 4000

    # same service over a sharded mesh (ShardedKBest, DESIGN.md §12):
    # every engine serves a --shards-way sharded index through the same
    # shape-bucketed compile cache (the cache key carries the mesh shape)
    PYTHONPATH=src python -m repro.launch.serve --mode ann --n 4000 --shards 2

    # beam-parallel traversal for the graph engine (DESIGN.md §2): W
    # expansions per lockstep iteration, same results floor, ~W x fewer
    # iterations per batch
    PYTHONPATH=src python -m repro.launch.serve --mode ann --beam 4

    # one decode step of a smoke LM with a KV cache (the decode_32k path)
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch gemma-2b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_ann(n: int, shards: int = 1, beam: int = 1):
    """Graph and IVF indexes served side by side through the batch-serving
    engine (repro.serve): mixed batch sizes and mixed k drain through one
    shape-bucketed compile cache per engine. shards > 1 builds each index
    as a ShardedKBest mesh (DESIGN.md §12) behind the same engines; beam > 1
    searches the graph engine with beam-parallel traversal (DESIGN.md §2 —
    the beam_width rides SearchConfig, so it is part of the cache key)."""
    from repro.core.index import KBest
    from repro.core.sharded import ShardedKBest
    from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                                  QuantConfig, SearchConfig)
    from repro.data.vectors import make_dataset
    from repro.serve import Request, SearchEngine, serve_loop

    def build(cfg, base):
        if cfg.n_shards > 1:
            return ShardedKBest(cfg).add(base)
        return KBest(cfg).add(base)

    ds = make_dataset("deep_like", n=n, n_queries=100, k=10)
    dim = ds.base.shape[1]
    graph = build(IndexConfig(
        dim=dim, metric=ds.metric, n_shards=shards,
        build=BuildConfig(M=32, knn_k=48, refine_iters=1, reorder="mst"),
        search=SearchConfig(L=64, k=10, early_term=True,
                            beam_width=beam)), ds.base)
    ivf = build(IndexConfig(
        dim=dim, metric=ds.metric, index_type="ivf", n_shards=shards,
        ivf=IVFConfig(kmeans_iters=6),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=6),
        search=SearchConfig(L=64, k=10, nprobe=8)), ds.base)

    engines = {"graph": SearchEngine(graph, max_bucket=16, name="graph"),
               "ivf": SearchEngine(ivf, max_bucket=16, name="ivf")}
    for e in engines.values():
        for kk in (5, 10):        # warm EVERY (bucket, k) the traffic emits,
            e.warmup(k=kk)        # or first-hit compiles pollute latencies

    rng = np.random.default_rng(0)
    requests, s = [], 0
    while s < len(ds.queries):
        b = int(rng.integers(4, 17))          # variable-size traffic
        e = min(s + b, len(ds.queries))
        requests.append(Request(
            queries=ds.queries[s:e], gt_ids=ds.gt_ids[s:e],
            engine=rng.choice(["graph", "ivf"]),
            k=int(rng.choice([5, 10]))))
        s = e

    t0 = time.perf_counter()
    report = serve_loop(engines, requests)
    dt = time.perf_counter() - t0
    print(f"{report.summary()} | wall {dt*1e3:.1f} ms (CPU interpret)")
    for name, st in sorted(report.engine_stats.items()):
        print(f"  [{name}] {st.summary()}")


def serve_lm(arch: str):
    from repro import configs as reg
    from repro.models import transformer as T
    cfg = reg.get(arch).smoke_config()
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
    logits, cache = step(p, cache, toks)          # compile
    t0 = time.perf_counter()
    for _ in range(16):
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = step(p, cache, nxt)
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / 16
    print(f"{arch}: {dt*1e3:.2f} ms/token (smoke config, CPU), "
          f"cache len={int(cache['len'][0])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("ann", "lm"), default="ann")
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--beam", type=int, default=1,
                    help="graph-engine beam width W (DESIGN.md §2)")
    ap.add_argument("--shards", type=int, default=1,
                    help="ShardedKBest mesh size for --mode ann (1 = plain)")
    args = ap.parse_args()
    if args.mode == "ann":
        serve_ann(args.n, shards=args.shards, beam=args.beam)
    else:
        serve_lm(args.arch)


if __name__ == "__main__":
    main()
