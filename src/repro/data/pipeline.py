"""Deterministic synthetic data pipelines with per-host sharding and
background prefetch.

Every stream is: (a) deterministic in (seed, host_id, step) — restart-safe
and bitwise reproducible across elastic re-sharding; (b) host-sharded (each
host generates only its slice of the global batch); (c) wrapped by
Prefetcher, a one-deep background-thread pipeline that overlaps host batch
synthesis with device compute (the host-side analogue of H2).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class Prefetcher:
    """Background-thread prefetch (depth-1 double buffering)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for x in self._it:
                self._q.put(x)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x


def _rng(seed: int, host: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, host, step]))


# ----------------------------------------------------------------- LM ------
def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               host_id: int = 0, n_hosts: int = 1,
               structured: bool = True) -> Iterator[Dict]:
    """Token batches (B_local, S+1). `structured` makes tokens learnable
    (Markov-ish repetition) so loss decreases in trainer tests."""
    assert batch % n_hosts == 0
    b_local = batch // n_hosts
    step = 0
    while True:
        r = _rng(seed, host_id, step)
        if structured:
            base = r.integers(0, vocab, size=(b_local, 8), dtype=np.int32)
            reps = int(np.ceil((seq + 1) / 8))
            toks = np.tile(base, (1, reps))[:, :seq + 1]
            noise = r.integers(0, vocab, size=toks.shape, dtype=np.int32)
            mask = r.random(toks.shape) < 0.05
            toks = np.where(mask, noise, toks)
        else:
            toks = r.integers(0, vocab, size=(b_local, seq + 1), dtype=np.int32)
        yield {"tokens": toks}
        step += 1


# -------------------------------------------------------------- recsys -----
def ctr_batches(n_fields: int, vocab: int, batch: int, seed: int = 0,
                host_id: int = 0, n_hosts: int = 1) -> Iterator[Dict]:
    """Criteo-like CTR batches with a planted logistic rule (learnable)."""
    b_local = batch // n_hosts
    step = 0
    w_plant = _rng(seed, 10_000, 0).normal(size=(n_fields,)).astype(np.float32)
    while True:
        r = _rng(seed, host_id, step)
        ids = r.integers(0, vocab, size=(b_local, n_fields), dtype=np.int32)
        score = ((ids % 97) / 97.0 - 0.5) @ w_plant
        label = (score + 0.3 * r.normal(size=b_local) > 0).astype(np.float32)
        yield {"sparse_ids": ids, "label": label}
        step += 1


def seq_batches(kind: str, n_items: int, batch: int, seq: int, seed: int = 0,
                host_id: int = 0, n_hosts: int = 1) -> Iterator[Dict]:
    """Behavior sequences for bst ("hist"+"target"+"label") and bert4rec
    ("seq"+"labels" with 15% masking)."""
    b_local = batch // n_hosts
    step = 0
    while True:
        r = _rng(seed, host_id, step)
        # sessions drift around a latent interest: random walk over items
        start = r.integers(0, n_items, size=(b_local, 1))
        walk = r.integers(-50, 51, size=(b_local, seq)).cumsum(axis=1)
        seqs = ((start + walk) % n_items).astype(np.int32)
        if kind == "bst":
            target = ((seqs[:, -1] + r.integers(-50, 51, size=b_local))
                      % n_items).astype(np.int32)
            label = (r.random(b_local) < 0.5).astype(np.float32)
            yield {"hist": seqs, "target": target, "label": label}
        else:
            labels = np.full((b_local, seq), -1, dtype=np.int32)
            mask = r.random((b_local, seq)) < 0.15
            labels[mask] = seqs[mask]
            masked = seqs.copy()
            masked[mask] = 0        # [MASK] id
            yield {"seq": masked, "labels": labels}
        step += 1


# ----------------------------------------------------------------- graph ---
def synthetic_graph(n_nodes: int, avg_degree: int, seed: int = 0):
    """CSR adjacency of a power-law-ish random graph (host-side numpy)."""
    r = np.random.default_rng(seed)
    deg = np.clip(r.zipf(1.6, size=n_nodes), 1, 20 * avg_degree)
    deg = (deg * (avg_degree / deg.mean())).astype(np.int64) + 1
    dst = r.integers(0, n_nodes, size=int(deg.sum()), dtype=np.int64)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    return indptr, dst


def sample_neighbors(indptr, indices, seeds: np.ndarray, fanout: int,
                     rng: np.random.Generator) -> np.ndarray:
    """Uniform neighbor sampling with replacement: (len(seeds), fanout)."""
    starts = indptr[seeds]
    degs = indptr[seeds + 1] - starts
    offs = (rng.random((len(seeds), fanout)) * np.maximum(degs, 1)[:, None]
            ).astype(np.int64)
    nbrs = indices[starts[:, None] + offs]
    nbrs[degs == 0] = seeds[degs == 0, None]   # isolated: self loop
    return nbrs


def gnn_minibatches(n_nodes: int, d_feat: int, batch_nodes: int,
                    fanouts=(15, 10), n_classes: int = 16, seed: int = 0,
                    host_id: int = 0, n_hosts: int = 1,
                    triplet_cap: int = 8) -> Iterator[Dict]:
    """2-hop sampled subgraph batches for DimeNet (the `minibatch_lg` shape).

    Real neighbor sampler over a synthetic CSR graph; outputs fixed-shape
    padded arrays: remapped local node ids, edge lists, capped triplets, and
    stub positions (modality frontend per DESIGN.md).
    """
    indptr, indices = synthetic_graph(n_nodes, avg_degree=25, seed=seed)
    b_local = batch_nodes // n_hosts
    # static sizes
    n1 = b_local * fanouts[0]
    n2 = n1 * fanouts[1]
    max_nodes = b_local + n1 + n2
    max_edges = n1 + n2
    max_trip = max_edges * triplet_cap
    step = 0
    while True:
        r = _rng(seed, host_id, step)
        seeds = r.integers(0, n_nodes, size=b_local, dtype=np.int64)
        h1 = sample_neighbors(indptr, indices, seeds, fanouts[0], r).reshape(-1)
        h2 = sample_neighbors(indptr, indices, h1, fanouts[1], r).reshape(-1)
        nodes, inv = np.unique(np.concatenate([seeds, h1, h2]),
                               return_inverse=True)
        n_loc = len(nodes)
        # edges: hop-1 (h1 -> seeds), hop-2 (h2 -> h1), in local ids
        src = np.concatenate([inv[b_local:b_local + n1],
                              inv[b_local + n1:]])
        dst = np.concatenate([np.repeat(inv[:b_local], fanouts[0]),
                              np.repeat(inv[b_local:b_local + n1], fanouts[1])])
        e = len(src)
        # triplets: for edge (j -> i), pair with up to cap edges (k -> j)
        order = np.argsort(dst, kind="stable")
        by_dst_start = np.searchsorted(dst[order], np.arange(n_loc))
        by_dst_end = np.searchsorted(dst[order], np.arange(n_loc) + 1)
        tkj, tji = [], []
        cnt = by_dst_end - by_dst_start
        for ei in range(e):
            j = src[ei]
            c = min(int(cnt[j]), triplet_cap)
            if c:
                ks = order[by_dst_start[j]:by_dst_start[j] + c]
                tkj.append(ks)
                tji.append(np.full(c, ei, dtype=np.int64))
        tkj = np.concatenate(tkj) if tkj else np.zeros(0, np.int64)
        tji = np.concatenate(tji) if tji else np.zeros(0, np.int64)

        def pad(a, size, fill=-1):
            out = np.full(size, fill, dtype=np.int32)
            out[:min(len(a), size)] = a[:size]
            return out

        feats = r.normal(size=(max_nodes, d_feat)).astype(np.float32)
        feats[n_loc:] = 0
        pos = r.normal(size=(max_nodes, 3)).astype(np.float32)
        labels = np.full(max_nodes, -1, np.int32)
        labels[:b_local] = (nodes[inv[:b_local]] % n_classes)
        yield {
            "feats": feats, "pos": pos,
            "edge_src": pad(src, max_edges), "edge_dst": pad(dst, max_edges),
            "trip_kj": pad(tkj, max_trip), "trip_ji": pad(tji, max_trip),
            "labels": labels,
        }
        step += 1


def molecule_batches(n_atoms: int, n_edges: int, batch: int, d_feat: int,
                     seed: int = 0, triplet_cap: int = 8) -> Iterator[Dict]:
    """Batched small molecules flattened into one padded graph (the
    `molecule` shape): radius-graph edges from random 3-D conformers."""
    step = 0
    N = n_atoms * batch
    E = n_edges * batch
    T = E * triplet_cap
    while True:
        r = _rng(seed, 0, step)
        pos = r.normal(size=(batch, n_atoms, 3)).astype(np.float32) * 1.5
        feats = r.normal(size=(N, d_feat)).astype(np.float32)
        src_l, dst_l, tkj_l, tji_l = [], [], [], []
        e_base = 0
        for g in range(batch):
            d = np.linalg.norm(pos[g][:, None] - pos[g][None], axis=-1)
            np.fill_diagonal(d, np.inf)
            # k-nearest edges per atom to hit ~n_edges per molecule
            k = max(1, n_edges // n_atoms)
            nb = np.argsort(d, axis=1)[:, :k]
            s = nb.reshape(-1) + g * n_atoms
            t = np.repeat(np.arange(n_atoms), k) + g * n_atoms
            src_l.append(s)
            dst_l.append(t)
            e_base += len(s)
        src = np.concatenate(src_l)[:E]
        dst = np.concatenate(dst_l)[:E]
        # triplets within the flat edge list
        order = np.argsort(dst, kind="stable")
        starts = np.searchsorted(dst[order], np.arange(N))
        ends = np.searchsorted(dst[order], np.arange(N) + 1)
        tkj, tji = [], []
        for ei in range(len(src)):
            j = src[ei]
            c = min(int(ends[j] - starts[j]), triplet_cap)
            if c:
                tkj.append(order[starts[j]:starts[j] + c])
                tji.append(np.full(c, ei, dtype=np.int64))
        tkj = np.concatenate(tkj) if tkj else np.zeros(0, np.int64)
        tji = np.concatenate(tji) if tji else np.zeros(0, np.int64)

        def pad(a, size):
            out = np.full(size, -1, dtype=np.int32)
            out[:min(len(a), size)] = a[:size]
            return out

        yield {
            "feats": feats,
            "pos": pos.reshape(N, 3),
            "edge_src": pad(src, E), "edge_dst": pad(dst, E),
            "trip_kj": pad(tkj, T), "trip_ji": pad(tji, T),
            "node_graph": np.repeat(np.arange(batch, dtype=np.int32), n_atoms),
            "targets": r.normal(size=batch).astype(np.float32),
        }
        step += 1
