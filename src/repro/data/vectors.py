"""Synthetic vector datasets matching the paper's Table 3 benchmarks.

No network access in this environment, so each dataset is a deterministic
synthetic analogue matched on (n, d, metric, query-distribution):

  glove_like  : 100-d, angular, heavy cluster structure (word vectors are
                famously clustered) -> gaussian mixture, normalized.
  deep_like   : 96-d, angular, smoother "real-world CNN descriptor"-ish
                distribution -> low-rank gaussian + noise, normalized.
  t2i_like    : 200-d, inner-product, OUT-OF-DISTRIBUTION queries (text
                queries vs image corpus) -> corpus from mixture A, queries
                from shifted mixture B (the paper's OOD robustness test).
  bigann_like : 128-d, L2, SIFT-ish non-negative clustered integers.

Sizes are scaled down by `scale` for CPU tests; the generator keeps the
structural knobs (cluster count, OOD shift) fixed so recall curves are
comparable across scales.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class VectorDataset:
    name: str
    metric: str
    base: np.ndarray      # (n, d) float32
    queries: np.ndarray   # (q, d) float32
    gt_ids: np.ndarray    # (q, k) int64 exact top-k ids under `metric`


def _mixture(key: np.random.Generator, n: int, d: int, n_clusters: int,
             spread: float, shift: float = 0.0, bg_frac: float = 0.15,
             rank: int = 16, basis: np.ndarray = None,
             center_scale: float = 0.8) -> np.ndarray:
    """Gaussian mixture with low-intrinsic-dimension cluster geometry plus a
    broad "background" component.

    Real embedding datasets are clustered but (i) have density bridges
    between clusters and (ii) live near a low-dimensional manifold, so
    inter-cluster distances vary smoothly and greedy routing has a gradient
    to follow. Isotropic random centers in d~100 are mutually
    near-orthogonal — pathological for ANY proximity-graph method and
    unrepresentative — so centers are drawn from a rank-`rank` subspace.
    """
    if basis is None:
        basis = key.normal(size=(rank, d)).astype(np.float32)
    rank = basis.shape[0]
    centers = (key.normal(size=(n_clusters, rank)).astype(np.float32) @ basis
               ) * center_scale + shift
    assign = key.integers(0, n_clusters, size=n)
    x = centers[assign] + key.normal(size=(n, d)).astype(np.float32) * spread
    n_bg = int(n * bg_frac)
    if n_bg:
        bg = (key.normal(size=(n_bg, rank)).astype(np.float32) @ basis
              ) * 1.25 * center_scale \
            + key.normal(size=(n_bg, d)).astype(np.float32) * spread + shift
        x[key.choice(n, n_bg, replace=False)] = bg
    return x.astype(np.float32)


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)


def exact_topk(base: np.ndarray, queries: np.ndarray, k: int, metric: str,
               chunk: int = 512) -> np.ndarray:
    """Exact ground truth by blocked brute force (numpy, host)."""
    out = np.empty((queries.shape[0], k), dtype=np.int64)
    for s in range(0, queries.shape[0], chunk):
        q = queries[s:s + chunk]
        if metric == "l2":
            d = ((q ** 2).sum(1)[:, None] + (base ** 2).sum(1)[None]
                 - 2.0 * q @ base.T)
        else:  # ip / cosine(pre-normalized)
            d = -(q @ base.T)
        out[s:s + chunk] = np.argpartition(d, k, axis=1)[:, :k]
        # exact ordering within the k set
        rows = np.arange(q.shape[0])[:, None]
        part = out[s:s + chunk]
        out[s:s + chunk] = part[rows, np.argsort(d[rows, part], axis=1)]
    return out


def make_dataset(name: str, n: int = 20_000, n_queries: int = 200,
                 k: int = 100, seed: int = 0) -> VectorDataset:
    import zlib
    # stable per-dataset seed: python's hash() is randomized per process
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)
    if name == "glove_like":
        d, metric = 100, "ip"
        # in-distribution: one draw, split into base/queries
        allx = _normalize(_mixture(rng, n + n_queries, d, n_clusters=48,
                                   spread=1.2))
        base, queries = allx[:n], allx[n:]
    elif name == "deep_like":
        d, metric = 96, "ip"
        rank = 32
        A = rng.normal(size=(rank, d)).astype(np.float32)
        allx = _normalize(
            rng.normal(size=(n + n_queries, rank)).astype(np.float32) @ A
            + 0.1 * rng.normal(size=(n + n_queries, d)).astype(np.float32))
        base, queries = allx[:n], allx[n:]
    elif name == "t2i_like":
        d, metric = 200, "ip"
        # OOD queries: SAME embedding subspace (the two towers land in a
        # shared space) but a different, shifted mixture (text vs image).
        basis = rng.normal(size=(24, d)).astype(np.float32)
        base = _mixture(rng, n, d, n_clusters=64, spread=1.0, basis=basis)
        queries = _mixture(rng, n_queries, d, n_clusters=24, spread=1.3,
                           shift=0.3, basis=basis)
        base /= np.sqrt(d)
        queries /= np.sqrt(d)
    elif name == "bigann_like":
        d, metric = 128, "l2"
        # SIFT-style non-negative ints via translation (L2-invariant, so the
        # search difficulty matches the underlying mixture, unlike abs()).
        allx = _mixture(rng, n + n_queries, d, n_clusters=64,
                        spread=1.0, rank=24, center_scale=2.0)
        allx = np.round((allx - allx.min()) * 10.0).astype(np.float32)
        base, queries = allx[:n], allx[n:]
    else:
        raise ValueError(name)
    gt = exact_topk(base, queries, k, metric)
    return VectorDataset(name=name, metric=metric, base=base,
                         queries=queries, gt_ids=gt)


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """recall@k = |found ∩ gt| / k averaged over queries (paper §2.1)."""
    hits = 0
    for f, g in zip(np.asarray(found_ids)[:, :k], gt_ids[:, :k]):
        hits += len(set(f.tolist()) & set(g.tolist()))
    return hits / (gt_ids.shape[0] * k)


ALL_DATASETS = ("glove_like", "deep_like", "t2i_like", "bigann_like")
