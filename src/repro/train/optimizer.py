"""Optimizers (no external deps — optax is not vendored here).

AdamW and Adafactor over pytrees, plus ZeRO-1 moment shardings. Adafactor's
factored second moment is what makes the 1T-param kimi-k2 cell fit: moments
for an (E, d, f) expert weight collapse from E*d*f to E*(d + f) floats.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999            # adafactor: decay exponent handled below
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    min_dim_factored: int = 2    # factor second moment for >=2-D params


# ------------------------------------------------------------------ AdamW --
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def _clip(grads, max_norm: float):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(grads, state, params, cfg: OptConfig):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = _clip(grads, cfg.grad_clip)
    c = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": c}, gnorm


# -------------------------------------------------------------- Adafactor --
def _factored(shape, cfg: OptConfig) -> bool:
    return len(shape) >= cfg.min_dim_factored


def adafactor_init(params, cfg: OptConfig = OptConfig(kind="adafactor")):
    def init(p):
        if _factored(p.shape, cfg):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, cfg: OptConfig):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = _clip(grads, cfg.grad_clip)
    c = state["count"] + 1
    # time-dependent decay (Shazeer & Stern): beta2_t = 1 - t^-0.8
    b2t = 1.0 - jnp.power(c.astype(jnp.float32), -0.8)

    def upd(p, g, v):
        g2 = g * g + 1e-30
        if _factored(p.shape, cfg):
            vr = b2t * v["vr"] + (1 - b2t) * jnp.mean(g2, axis=-1)
            vc = b2t * v["vc"] + (1 - b2t) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            pre = jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
            step = g / jnp.maximum(pre, cfg.eps)
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = b2t * v["v"] + (1 - b2t) * g2
            step = g / (jnp.sqrt(vv) + cfg.eps)
            new_v = {"v": vv}
        # update clipping (RMS <= 1) as in the paper
        rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * step).astype(p.dtype), new_v

    # params/grads leaves are arrays; state["v"] has params as a tree-prefix
    # (each param leaf maps to a {"v"} or {"vr","vc"} dict), which tree_map
    # passes through whole.
    flat = jax.tree_util.tree_map(upd, params, grads, state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"v": new_v, "count": c}, gnorm


# ---------------------------------------------------------------- facade ---
def opt_init(params, cfg: OptConfig):
    if cfg.kind == "adamw":
        return adamw_init(params)
    return adafactor_init(params, cfg)


def opt_update(grads, state, params, cfg: OptConfig):
    if cfg.kind == "adamw":
        return adamw_update(grads, state, params, cfg)
    return adafactor_update(grads, state, params, cfg)
