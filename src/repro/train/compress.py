"""Gradient compression for the DP all-reduce (DESIGN.md §6).

int8 uniform quantization with per-leaf scale and *error feedback* (the
residual of each quantization step is carried into the next step's gradient
— Seide et al. 1-bit SGD / EF-SGD): convergence matches uncompressed SGD up
to higher-order terms while shrinking the DP all-reduce payload 4x (fp32)
or 2x (bf16).

Usage: wrap the per-shard gradients inside a shard_map'd train step:

    g_q, new_residual = compress_decompress(g, residual)   # per-device
    g_sync = jax.lax.pmean(g_q, axis_name=dp_axes)

The quantized tensors are what crosses the links; pmean of int8-decoded
values is exact in fp32. Residual lives in the optimizer state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_leaf(g: jnp.ndarray, r: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32) + r
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq        # value-to-sync, new residual


def compress_decompress(grads, residual):
    """Returns (dequantized grads to all-reduce, new residual tree)."""
    pairs = jax.tree.map(_quant_leaf, grads, residual)
    deq = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, res
