"""Fault-tolerant training loop (DESIGN.md §6).

Production posture on thousands of nodes requires, at minimum:
  * periodic + signal-triggered checkpoints with atomic commit,
  * automatic resume from the latest valid checkpoint,
  * straggler detection (per-step wall-time EMA; in multi-host deployments
    the hook triggers re-meshing, here it logs + counts),
  * elastic re-mesh: a checkpoint written under mesh A restores under a
    different mesh B (reshard-on-restore; see checkpoint.restore),
  * failure injection for testing the above end-to-end.

The Trainer is model-agnostic: it takes loss_fn(params, batch) -> (loss,
metrics), an optimizer config, shardings for params/batch, and a data
iterator.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_mod
from repro.train.optimizer import OptConfig, opt_init, opt_update


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    straggler_kappa: float = 2.5   # step > kappa * EMA => straggler
    ema_alpha: float = 0.1
    fail_at_step: int = -1         # failure injection (tests)
    log_every: int = 10


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, loss_fn: Callable, opt_cfg: OptConfig,
                 cfg: TrainerConfig, param_shardings=None,
                 batch_shardings=None, donate: bool = True):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.ckpt = ckpt_mod.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_last)
        self.straggler_steps = 0
        self._ema = None
        self._warm = None
        self._stop = False

        def step_fn(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            new_params, new_state, gnorm = opt_update(
                grads, opt_state, params, self.opt_cfg)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return new_params, new_state, metrics

        kwargs = {}
        if param_shardings is not None:
            kwargs["in_shardings"] = (param_shardings, None, batch_shardings)
        if donate:
            kwargs["donate_argnums"] = (0, 1)
        self.step_fn = jax.jit(step_fn, **kwargs)

    # ------------------------------------------------------------- signals
    def install_signal_handler(self):
        def handler(signum, frame):
            self._stop = True   # checkpoint + exit at the next step boundary
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # --------------------------------------------------------------- train
    def fit(self, params, data: Iterator, n_steps: int,
            resume: bool = True) -> dict:
        opt_state = opt_init(params, self.opt_cfg)
        start = 0
        if resume:
            last = ckpt_mod.latest_step(self.cfg.ckpt_dir)
            if last is not None:
                state = ckpt_mod.restore(
                    self.cfg.ckpt_dir, last,
                    {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                start = last
        history = []
        for step in range(start, n_steps):
            if self._stop:
                break
            if step == self.cfg.fail_at_step:
                # crash AFTER the last checkpoint, BEFORE saving this step:
                # the restart path must recover.
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()   # includes data stalls: they ARE a
            batch = next(data)         # straggler symptom at fleet scale
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._track_straggler(dt, step)
            if step % self.cfg.log_every == 0:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "sec": dt})
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
        self.ckpt.save(n_steps if not self._stop else step,
                       {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return {"params": params, "opt": opt_state, "history": history,
                "stragglers": self.straggler_steps}

    def _track_straggler(self, dt: float, step: int) -> None:
        if self._warm is None:
            self._warm = True   # step 0 includes jit compile: never seed EMA
            return
        if self._ema is None:
            self._ema = dt
            return
        if dt > self.cfg.straggler_kappa * self._ema:
            self.straggler_steps += 1
        a = self.cfg.ema_alpha
        self._ema = (1 - a) * self._ema + a * dt


def reshard_checkpoint(ckpt_dir: str, step: int, like_tree, new_shardings):
    """Elastic re-mesh: restore a checkpoint under a different mesh."""
    return ckpt_mod.restore(ckpt_dir, step, like_tree, new_shardings)
