"""Sharded, atomic, mesh-independent checkpointing.

Format: a directory per step
    step_000120/
      arrays.npz        flattened {leaf_key: array} (this host's data)
      meta.json         {"step": int, "keys": [...], "treedef": repr}
      _DONE             commit marker (atomicity: written last)

Properties needed at 1000-node scale, all present here:
  * atomic commit (tmp dir + rename + _DONE marker) — a killed save never
    corrupts the latest-valid pointer;
  * auto-resume: latest_step() scans for the newest _DONE;
  * mesh independence: arrays are saved logically (full value per leaf via
    multihost gather on real clusters; single-process here) and restored
    with device_put against the *target* mesh's shardings — restarts may
    change topology (elastic downscale, §4);
  * retention: keep_last pruning;
  * async: save_async offloads serialization to a worker thread so the
    training loop only pays the host-transfer cost.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[dict, Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, step: int, tree, keep_last: int = 3) -> str:
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, treedef = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, "keys": sorted(arrays.keys())}))
    (tmp / "_DONE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(root, keep_last)
    return str(final)


def _prune(root: Path, keep_last: int) -> None:
    done = sorted(p for p in root.glob("step_*") if (p / "_DONE").exists())
    for p in done[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    root = Path(path)
    if not root.exists():
        return None
    done = sorted(p for p in root.glob("step_*") if (p / "_DONE").exists())
    if not done:
        return None
    return int(done[-1].name.split("_")[1])


def restore(path: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; device_put against
    `shardings` (same structure) if given — this is the elastic-remesh
    entry point: shardings may come from a different mesh than the save."""
    d = Path(path) / f"step_{step:08d}"
    assert (d / "_DONE").exists(), f"checkpoint {d} incomplete"
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    out_leaves = []
    for path_keys, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = arrays[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out_leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (one in flight at a time)."""

    def __init__(self, path: str, keep_last: int = 3):
        self.path = path
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host in caller

        def work():
            try:
                save(self.path, step, host_tree, self.keep_last)
            except Exception as e:       # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
