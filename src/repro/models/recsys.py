"""RecSys architectures: deepfm, fm, bst, bert4rec.

Common substrate (kernel_taxonomy §RecSys, DESIGN.md):
  * huge sparse embedding tables — a stacked (F, V, D) per-field table,
    looked up via jnp.take; multi-hot bags via layers.common.embedding_bag
    (take + segment/masked reduce: JAX has no native EmbeddingBag).
  * feature interaction: FM sum-square trick (O(F*D), Rendle ICDM'10),
    self-attention over behavior sequences (BST), bidirectional encoder
    (BERT4Rec).
  * retrieval_cand serving: score ONE query vector against 10^6 candidate
    item embeddings. This is exactly the paper's workload — the path runs
    either the batch_dist MXU kernel (exact, batched-dot) or a prebuilt
    KBest graph index (sub-linear ANN). See serve_retrieval().

Shapes (assigned): train_batch 65536 / serve_p99 512 / serve_bulk 262144 /
retrieval_cand 1 x 1e6.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.layers import common as L


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                    # "deepfm" | "fm" | "bst" | "bert4rec"
    n_sparse: int = 39           # categorical fields (deepfm / fm)
    vocab_per_field: int = 100_000
    embed_dim: int = 10
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    # sequence models
    n_items: int = 1_000_000     # item vocabulary (bst / bert4rec / retrieval)
    seq_len: int = 200
    n_blocks: int = 2
    n_heads: int = 2
    d_model: int = 64            # bert4rec embed_dim / bst transformer dim
    dtype: str = "float32"
    unroll_blocks: bool = False  # cost-analysis mode (see launch/dryrun)
    masked_positions: int = 0    # bert4rec (hillclimb D): compute softmax
                                 # logits ONLY at <=P masked positions per
                                 # row instead of all S x V — kills the
                                 # (B, S, V) temp blow-up at V=10^6

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- params ---
def init_params(cfg: RecsysConfig, key) -> dict:
    dt = cfg.param_dtype
    ks = iter(jax.random.split(key, 16 + 4 * cfg.n_blocks))

    def dense(shape, scale=None):
        return L.dense_init(next(ks), shape, scale=scale, dtype=dt)

    if cfg.kind in ("deepfm", "fm"):
        F, V, D = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
        p = {
            "tables": dense((F, V, D), scale=0.01),
            "linear": dense((F, V), scale=0.01),     # per-field scalar weights
            "bias": jnp.zeros((), jnp.float32),
        }
        if cfg.kind == "deepfm":
            dims = (F * D,) + tuple(cfg.mlp_dims) + (1,)
            p["mlp"] = [
                {"w": dense((dims[i], dims[i + 1])),
                 "b": jnp.zeros((dims[i + 1],), dt)}
                for i in range(len(dims) - 1)
            ]
        return p

    if cfg.kind == "bst":
        Dm = cfg.d_model
        p = {
            "item_emb": dense((cfg.n_items, Dm), scale=0.02),
            "pos_emb": dense((cfg.seq_len + 1, Dm), scale=0.02),
            "blocks": _init_blocks(cfg, ks, Dm),
            "mlp": [],
        }
        dims = ((cfg.seq_len + 1) * Dm,) + tuple(cfg.mlp_dims) + (1,)
        p["mlp"] = [{"w": dense((dims[i], dims[i + 1])),
                     "b": jnp.zeros((dims[i + 1],), dt)}
                    for i in range(len(dims) - 1)]
        return p

    if cfg.kind == "bert4rec":
        Dm = cfg.d_model
        return {
            "item_emb": dense((cfg.n_items, Dm), scale=0.02),
            "pos_emb": dense((cfg.seq_len, Dm), scale=0.02),
            "blocks": _init_blocks(cfg, ks, Dm),
            "ln_f": jnp.zeros((Dm,), jnp.float32),
        }
    raise ValueError(cfg.kind)


def _init_blocks(cfg, ks, Dm):
    import jax
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "ln1": jnp.zeros((Dm,), jnp.float32),
            "ln2": jnp.zeros((Dm,), jnp.float32),
            "wq": L.dense_init(next(ks), (Dm, Dm), dtype=cfg.param_dtype),
            "wk": L.dense_init(next(ks), (Dm, Dm), dtype=cfg.param_dtype),
            "wv": L.dense_init(next(ks), (Dm, Dm), dtype=cfg.param_dtype),
            "wo": L.dense_init(next(ks), (Dm, Dm), dtype=cfg.param_dtype),
            "w_in": L.dense_init(next(ks), (Dm, 4 * Dm), dtype=cfg.param_dtype),
            "w_out": L.dense_init(next(ks), (4 * Dm, Dm), dtype=cfg.param_dtype),
        })
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


# -------------------------------------------------------------- encoders ---
def _mlp_head(mlp, x, dt):
    h = x
    for i, lyr in enumerate(mlp):
        h = h @ lyr["w"] + lyr["b"]
        if i < len(mlp) - 1:
            h = jax.nn.relu(h)
    return h


def _encoder(blocks, x, cfg, causal: bool):
    """Tiny pre-LN transformer encoder via scan. x: (B, S, Dm)."""
    B, S, Dm = x.shape
    H = cfg.n_heads
    hd = Dm // H

    def body(x, bp):
        hin = L.rms_norm(x, bp["ln1"])
        q = hin @ bp["wq"]
        k = hin @ bp["wk"]
        v = hin @ bp["wv"]
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, S, H, hd)
        v = v.reshape(B, S, H, hd)
        a = L.gqa_attention(q, k, v, causal=causal)
        x = x + a.reshape(B, S, Dm) @ bp["wo"]
        hin = L.rms_norm(x, bp["ln2"])
        x = x + jax.nn.gelu(hin @ bp["w_in"]) @ bp["w_out"]
        return x, None

    if cfg.unroll_blocks:
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda t: t[i], blocks)
            x, _ = body(x, bp)
    else:
        x, _ = jax.lax.scan(body, x, blocks)
    return x


def _fm_terms(params, ids, cfg):
    """Shared FM machinery. ids: (B, F) -> (linear+fm logit, field embs)."""
    F = cfg.n_sparse
    fidx = jnp.arange(F)[None, :]
    emb = params["tables"][fidx, ids]             # (B, F, D)
    lin = params["linear"][fidx, ids]             # (B, F)
    s = jnp.sum(emb, axis=1)                      # sum-square trick, O(F*D)
    fm = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
    logit = params["bias"] + jnp.sum(lin, axis=1) + fm
    return logit.astype(jnp.float32), emb


# ---------------------------------------------------------------- scoring --
def forward(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """Returns per-example logits.

    deepfm/fm: batch {"sparse_ids": (B, F)}; bst: {"hist": (B, S),
    "target": (B,)}; bert4rec: {"seq": (B, S)} -> (B, S, n_items) logits.
    """
    dt = cfg.param_dtype
    if cfg.kind == "fm":
        logit, _ = _fm_terms(params, batch["sparse_ids"], cfg)
        return logit
    if cfg.kind == "deepfm":
        logit, emb = _fm_terms(params, batch["sparse_ids"], cfg)
        B = emb.shape[0]
        deep = _mlp_head(params["mlp"], emb.reshape(B, -1), dt)[:, 0]
        return logit + deep.astype(jnp.float32)
    if cfg.kind == "bst":
        hist, target = batch["hist"], batch["target"]       # (B,S), (B,)
        B, S = hist.shape
        seq = jnp.concatenate([hist, target[:, None]], axis=1)
        x = params["item_emb"][seq] + params["pos_emb"][None]
        x = _encoder(params["blocks"], x.astype(dt), cfg, causal=False)
        out = _mlp_head(params["mlp"], x.reshape(B, -1), dt)[:, 0]
        return out.astype(jnp.float32)
    if cfg.kind == "bert4rec":
        seq = batch["seq"]                                   # (B, S)
        x = params["item_emb"][seq] + params["pos_emb"][None]
        x = _encoder(params["blocks"], x.astype(dt), cfg, causal=False)
        x = L.rms_norm(x, params["ln_f"])
        logits = x @ params["item_emb"].T.astype(x.dtype)    # tied softmax
        return logits.astype(jnp.float32)
    raise ValueError(cfg.kind)


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> Tuple:
    if cfg.kind == "bert4rec" and cfg.masked_positions > 0:
        return _bert4rec_masked_loss(params, batch, cfg)
    out = forward(params, batch, cfg)
    if cfg.kind == "bert4rec":
        labels = batch["labels"]                             # (B, S), -1 ignore
        mask = labels >= 0
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(
            logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    else:
        y = batch["label"].astype(jnp.float32)
        loss = jnp.mean(
            jnp.maximum(out, 0) - out * y + jnp.log1p(jnp.exp(-jnp.abs(out))))
    return loss, {"loss": loss}


def _bert4rec_masked_loss(params: dict, batch: dict, cfg: RecsysConfig
                          ) -> Tuple:
    """Masked-LM loss evaluated ONLY at masked positions (hillclimb D).

    Baseline materializes (B, S, V) logits — 65536*200*1e6 fp32 is the
    782 GiB/device temp observed in the dry-run. Only ~15% of positions
    carry labels; gathering the <=P labelled encodings per row BEFORE the
    tied-softmax matmul shrinks every logits buffer by S/P. Loss is
    identical whenever a row has <= P masked positions (choose P above the
    masking budget: 0.15*200=30 -> P=40); rows beyond the cap drop excess
    positions (standard fixed-budget masking).
    """
    seq, labels = batch["seq"], batch["labels"]              # (B, S)
    B, S = seq.shape
    P_ = min(cfg.masked_positions, S)
    x = params["item_emb"][seq] + params["pos_emb"][None]
    x = _encoder(params["blocks"], x.astype(cfg.param_dtype), cfg,
                 causal=False)
    x = L.rms_norm(x, params["ln_f"])                        # (B, S, Dm)
    # top-P positions by mask flag (stable w.r.t. position order)
    is_m = (labels >= 0).astype(jnp.int32)
    _, pos = jax.lax.top_k(is_m * (S - jnp.arange(S)) , P_)  # masked first
    xg = jnp.take_along_axis(x, pos[..., None], axis=1)      # (B, P, Dm)
    lg = jnp.take_along_axis(labels, pos, axis=1)            # (B, P)
    logits = (xg @ params["item_emb"].T.astype(xg.dtype)).astype(jnp.float32)
    mask = lg >= 0
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(lg, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"loss": loss}


def serve_step(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """Online/bulk scoring: one logit per example.

    fm/deepfm/bst: forward() already is pairwise scoring. bert4rec: scoring
    a (user-sequence, candidate) pair = dot of the last-position encoding
    with the candidate's item embedding (standard eval protocol; computing
    the full (B, S, V) softmax for serving would be nonsense at V=10^6).
    batch for bert4rec: {"seq": (B, S), "cand": (B,)}.
    """
    if cfg.kind != "bert4rec":
        return forward(params, batch, cfg)
    u = query_vector(params, batch, cfg)                     # (B, Dm)
    c = params["item_emb"][batch["cand"]].astype(jnp.float32)
    return jnp.sum(u * c, axis=-1)


# --------------------------------------------------------------- retrieval -
def query_vector(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """User/query embedding for retrieval (the ANN query).

    fm/deepfm: sum of user-field embedding vectors — FM's score of item i
    against user fields is <v_i, sum_f v_f> + lin_i, so retrieval reduces
    exactly to inner-product search (Rendle's trick).
    bst/bert4rec: sequence-encoder output at the last position (SASRec-style
    next-item retrieval).
    """
    dt = cfg.param_dtype
    if cfg.kind in ("fm", "deepfm"):
        _, emb = _fm_terms(params, batch["sparse_ids"], cfg)
        return jnp.sum(emb, axis=1).astype(jnp.float32)      # (B, D)
    if cfg.kind == "bst":
        hist = batch["hist"]
        x = params["item_emb"][hist] + params["pos_emb"][None, :hist.shape[1]]
        x = _encoder(params["blocks"], x.astype(dt), cfg, causal=False)
        return x[:, -1].astype(jnp.float32)
    if cfg.kind == "bert4rec":
        seq = batch["seq"]
        x = params["item_emb"][seq] + params["pos_emb"][None]
        x = _encoder(params["blocks"], x.astype(dt), cfg, causal=False)
        x = L.rms_norm(x, params["ln_f"])
        return x[:, -1].astype(jnp.float32)
    raise ValueError(cfg.kind)


def candidate_table(params: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """The corpus being searched in retrieval_cand."""
    if cfg.kind in ("fm", "deepfm"):
        # item corpus = embeddings of field 0 (the "item id" field)
        return params["tables"][0].astype(jnp.float32)       # (V, D)
    return params["item_emb"].astype(jnp.float32)            # (n_items, Dm)


def serve_retrieval(params: dict, batch: dict, cfg: RecsysConfig, k: int = 100,
                    use_kernel: bool = False, shard_topk: int = 0,
                    shard_axis: str = "model"
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact retrieval: 1-to-B batched inner product over all candidates
    (the paper's H1 workload at B = n_candidates) + top-k. The sub-linear
    alternative builds a KBest index over candidate_table() — see
    examples/retrieval_recsys.py.

    shard_topk > 0 (hillclimb C): the candidate table is row-sharded over
    `shard_axis`; the naive path makes XLA all-gather the FULL (B, V) score
    row to run the global top-k. Instead reshape scores into (B, S, V/S)
    pinned so chunk s lives on shard s, take a LOCAL top-k per shard (the
    exact pattern of core.sharded's sharded search merge), and only the
    (B, S*k) candidates cross the interconnect — V/(S*k) ~ 600x less.
    """
    q = query_vector(params, batch, cfg)                     # (B, D)
    cands = candidate_table(params, cfg)                     # (V, D)
    if use_kernel:
        from repro.kernels import ops as kops
        d = kops.batch_dist(q, cands, metric="ip")
    else:
        d = -(q @ cands.T)
    if shard_topk > 1:
        from jax.sharding import PartitionSpec as P
        B, V = d.shape
        S = shard_topk
        ds_ = d.reshape(B, S, V // S)
        ds_ = jax.lax.with_sharding_constraint(ds_, P(None, shard_axis, None))
        neg_l, ids_l = jax.lax.top_k(-ds_, k)                # local top-k
        base = (jnp.arange(S, dtype=jnp.int32) * (V // S))[None, :, None]
        ids_l = ids_l + base
        neg_l = jax.lax.with_sharding_constraint(
            neg_l, P(None, shard_axis, None))
        neg, pos = jax.lax.top_k(neg_l.reshape(B, S * k), k)  # global merge
        ids = jnp.take_along_axis(ids_l.reshape(B, S * k), pos, axis=1)
        return -neg, ids
    neg, ids = jax.lax.top_k(-d, k)
    return -neg, ids


def serve_retrieval_shardmap(params: dict, batch: dict, cfg: RecsysConfig,
                             mesh, k: int = 100, axis: str = "model"
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit-collective retrieval (hillclimb C, the paper's distributed
    search merge): scores and top-k are computed PER candidate shard under
    shard_map, so only (n_shards, B, k) candidate tuples ever cross the
    interconnect — the GSPMD/Shardy auto-partitioner was observed to
    all-gather the full (B, V) score row instead (V/(n*k) ~ 600x more).
    Identical results to serve_retrieval (exact search)."""
    from jax.sharding import PartitionSpec as P

    q = query_vector(params, batch, cfg)                     # (B, D) repl.
    cands = candidate_table(params, cfg)                     # (V, D) sharded

    def local(q_l, c_l):
        d = -(q_l @ c_l.T)                                   # (B, V/n)
        neg, ids = jax.lax.top_k(-d, k)                      # local top-k
        off = jax.lax.axis_index(axis) * c_l.shape[0]
        ids = ids + off
        all_neg = jax.lax.all_gather(neg, axis)              # (n, B, k)
        all_ids = jax.lax.all_gather(ids, axis)
        n = all_neg.shape[0]
        B = q_l.shape[0]
        flat_neg = all_neg.transpose(1, 0, 2).reshape(B, n * k)
        flat_ids = all_ids.transpose(1, 0, 2).reshape(B, n * k)
        mneg, pos = jax.lax.top_k(flat_neg, k)
        return -mneg, jnp.take_along_axis(flat_ids, pos, axis=1)

    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(), P(axis, None)),
                       out_specs=(P(), P()), check_vma=False)
    return fn(q, cands)
