"""Decoder-only transformer LM (dense + MoE) covering the five assigned
LM architectures (qwen2.5-14b, chatglm3-6b, gemma-2b, kimi-k2-1t-a32b,
llama4-scout-17b-a16e).

One parameterized implementation:
  * GQA / MQA attention (n_kv_heads), optional QKV bias (qwen),
    head_dim override (gemma 256), rotary_frac (chatglm 2-d RoPE = 0.5),
    GeGLU vs SwiGLU vs plain MLP, optional sliding window,
  * MoE layers with sort-based dispatch (kimi, llama4-scout),
  * layers stacked + lax.scan'd (compact HLO at 61 layers) with optional
    remat (activation checkpointing policy per arch),
  * train path: full-sequence causal LM loss,
  * serve path: single-token decode against a preallocated KV cache
    (decode_* / long_* dry-run shapes).

Params are plain dict trees; sharding/rules.py maps path -> PartitionSpec.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers import common as L
from repro.layers.moe import MoEConfig, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    act: str = "silu"            # mlp activation; "geglu" => gelu-gated
    gated_mlp: bool = True
    qkv_bias: bool = False
    rotary_frac: float = 1.0     # chatglm "2d" rope = 0.5
    rope_base: float = 10_000.0
    tie_embeddings: bool = False
    window: int = 0              # sliding-window attention (0 = full)
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    moe_every: int = 1           # apply MoE on layers where i % moe_every == 0
    remat: bool = True
    remat_policy: str = "full"   # "full" | "dots": checkpoint_dots saves
                                 # GEMM outputs, so the backward re-runs only
                                 # pointwise ops — measured to remove the
                                 # ~25% of per-layer collective bytes that
                                 # full remat re-executes (EXPERIMENTS §Perf)
    dtype: str = "bfloat16"      # params/activation dtype ("float32" on CPU tests)
    unroll_layers: bool = False  # python-loop the layer stack (cost analysis:
                                 # XLA counts a scan body once; see dryrun)
    loss_vocab_axis: str = ""    # hillclimb: keep train logits bf16 AND
                                 # vocab-sharded over this mesh axis; loss
                                 # uses fused sharded reductions instead of
                                 # materializing replicated f32 (B,S,V)
    loss_batch_axes: tuple = ()  # mesh axes the batch dim stays sharded on
                                 # in the loss (must accompany loss_vocab_axis
                                 # or the logits become batch-replicated)
    loss_vocab_shards: int = 0   # size of loss_vocab_axis (static, for the
                                 # shard-blocked reshape of the loss)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- params ---
def init_params(cfg: LMConfig, key) -> dict:
    dt = cfg.param_dtype
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    d, hd = cfg.d_model, cfg.hd

    def layer(k):
        ks = jax.random.split(k, 8)
        p = {
            "ln1": jnp.zeros((d,), jnp.float32),
            "ln2": jnp.zeros((d,), jnp.float32),
            "wq": L.dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dt),
            "wk": L.dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dt),
            "wv": L.dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dt),
            "wo": L.dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dt),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
            p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
            p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        if cfg.moe is not None:
            p["moe"] = init_moe(ks[4], d, cfg.moe, dtype=dt)
        else:
            p["w_in"] = L.dense_init(ks[5], (d, cfg.d_ff), dtype=dt)
            if cfg.gated_mlp:
                p["w_gate"] = L.dense_init(ks[6], (d, cfg.d_ff), dtype=dt)
            p["w_out"] = L.dense_init(ks[7], (cfg.d_ff, d), dtype=dt)
        return p

    keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(layer)(keys)          # stacked: every leaf (L, ...)
    params = {
        "embed": L.dense_init(k_emb, (cfg.vocab, d), scale=0.02, dtype=dt),
        "layers": layers,
        "ln_f": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, (d, cfg.vocab), dtype=dt)
    return params


# --------------------------------------------------------------- forward ---
def _scan_layers(body, carry, stacked, cfg: LMConfig):
    """lax.scan over the stacked layer params, or an unrolled python loop
    (identical math; used by the dry-run's cost extrapolation)."""
    if cfg.remat and cfg.remat_policy == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    elif cfg.remat and cfg.remat_policy == "dots_nb":
        # save projection/attention GEMMs; recompute the (E, C, *) expert
        # GEMMs (they carry a batch dim) — collective-vs-memory middle ground
        body_fn = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    if not cfg.unroll_layers:
        return jax.lax.scan(body_fn, carry, stacked)
    ys = []
    for i in range(cfg.n_layers):
        layer = jax.tree.map(lambda x: x[i], stacked)
        carry, y = body_fn(carry, layer)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
    else:
        ys = None
    return carry, ys


def _mlp(p: dict, x: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    act = L.act_fn("gelu" if cfg.act == "geglu" else cfg.act)
    h = x @ p["w_in"]
    if cfg.gated_mlp:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    return h @ p["w_out"]


def _attn(p: dict, x: jnp.ndarray, cfg: LMConfig, positions: jnp.ndarray,
          cache_kv: Optional[Tuple] = None, kv_len=None):
    """x: (B, S, d). cache_kv: (k_cache, v_cache) (B, T, Hkv, D) for decode."""
    B, S, d = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_base, cfg.rotary_frac)
    k = L.apply_rope(k, positions, cfg.rope_base, cfg.rotary_frac)

    new_cache = None
    if cache_kv is not None:
        kc, vc = cache_kv                    # (B, T, Hkv, D)
        # write the S new tokens at kv_len (decode: S == 1)
        idx = kv_len[:, None] + jnp.arange(S)[None]               # (B, S)
        bidx = jnp.arange(B)[:, None]
        kc = kc.at[bidx, idx].set(k.astype(kc.dtype))
        vc = vc.at[bidx, idx].set(v.astype(vc.dtype))
        k, v = kc, vc
        new_cache = (kc, vc)
        out = L.gqa_attention(q, k, v, causal=True, window=cfg.window,
                              q_offset=kv_len, kv_len=kv_len + S)
    else:
        out = L.gqa_attention(q, k, v, causal=True, window=cfg.window)
    return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"], new_cache


def _block(p: dict, x: jnp.ndarray, cfg: LMConfig, positions, cache_kv=None,
           kv_len=None):
    h, new_cache = _attn(p, L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                         positions, cache_kv, kv_len)
    x = x + h
    hin = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.moe is not None:
        from repro.layers.moe import moe_ffn_shardmap
        B, S, d = hin.shape
        fn = moe_ffn_shardmap if cfg.moe.use_shardmap else moe_ffn
        out, aux = fn(p["moe"], hin.reshape(B * S, d), cfg.moe)
        out = out.reshape(B, S, d)
    else:
        out = _mlp(p, hin, cfg)
    return x + out, aux, new_cache


def forward_features(params: dict, tokens: jnp.ndarray, cfg: LMConfig
                     ) -> Tuple:
    """Backbone only: tokens (B, S) -> (final hidden (B, S, d), aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.param_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)  # gemma
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, layer_p):
        x, aux = carry
        x, a, _ = _block(layer_p, x, cfg, positions)
        return (x, aux + a), None

    (x, aux), _ = _scan_layers(body, (x, jnp.float32(0.0)),
                               params["layers"], cfg)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), aux


def _head(params: dict, x: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ params["embed"].T.astype(x.dtype)
    return x @ params["unembed"]


def forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig) -> Tuple:
    """Training forward. tokens: (B, S) -> (logits (B, S, V), aux_loss)."""
    x, aux = forward_features(params, tokens, cfg)
    return _head(params, x, cfg).astype(jnp.float32), aux


def loss_fn(params: dict, batch: dict, cfg: LMConfig) -> Tuple:
    """Causal LM loss. batch: {"tokens": (B, S+1) int32}.

    With cfg.loss_vocab_axis set (hillclimb), the (B, S, V) logits stay
    bf16 AND vocab-sharded; softmax statistics use fused reductions over
    the sharded V (tiny (B, S) psums) and the target logit is extracted by
    a fused select+reduce instead of a gather — the dry-run showed the
    naive path forcing a replicated f32 (B, S, V) all-reduce (40 GiB at
    kimi-k2 scale).
    """
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    if not cfg.loss_vocab_axis:
        logits, aux = forward(params, inp, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll) + aux
        return loss, {"nll": jnp.mean(nll), "aux": aux}

    from jax.sharding import PartitionSpec as P
    x, aux = forward_features(params, inp, cfg)
    bx0 = tuple(cfg.loss_batch_axes) or None
    # features must enter the head with FULL d: a d-sharded input makes the
    # head a contraction-sharded GEMM whose partial sums psum the full-V
    # f32 logits (40 GiB/step observed); gathering (B, S, d) bf16 is ~40x
    # cheaper.
    x = jax.lax.with_sharding_constraint(x, P(bx0, None, None))
    logits = _head(params, x, cfg)                    # bf16, (B, S, V)
    B, S, V = logits.shape
    n = max(cfg.loss_vocab_shards, 1)
    bx = tuple(cfg.loss_batch_axes) or None
    # shard-blocked softmax: reshape V into (n, V/n) pinned so block j
    # lives on vocab-shard j — all O(V) reductions become LOCAL; only the
    # (B, S, n) per-block statistics cross shards. (Leaving the layout to
    # the partitioner was observed to replicate the f32 logits instead.)
    lr = logits.reshape(B, S, n, V // n)
    lr = jax.lax.with_sharding_constraint(
        lr, P(bx, None, cfg.loss_vocab_axis, None))
    lf = lr.astype(jnp.float32)
    m_l = jnp.max(lf, axis=-1)                        # (B, S, n)
    s_l = jnp.sum(jnp.exp(lf - m_l[..., None]), axis=-1)
    m = jnp.max(m_l, axis=-1)                         # (B, S)
    lse = m + jnp.log(jnp.sum(s_l * jnp.exp(m_l - m[..., None]), axis=-1))
    # target logit: local select inside the owning block
    iota = jax.lax.broadcasted_iota(jnp.int32, lr.shape, 3) \
        + jax.lax.broadcasted_iota(jnp.int32, lr.shape, 2) * (V // n)
    tgt_logit = jnp.sum(
        jnp.where(iota == tgt[..., None, None], lf, 0.0), axis=(-1, -2))
    nll = lse - tgt_logit
    loss = jnp.mean(nll) + aux
    return loss, {"nll": jnp.mean(nll), "aux": aux}


def prefill(params: dict, tokens: jnp.ndarray, cfg: LMConfig
            ) -> Tuple[jnp.ndarray, dict]:
    """Inference prefill: full-sequence forward that also materializes the
    KV cache (the prefill_32k dry-run shape). Returns (last-token logits,
    cache sized exactly to S)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.param_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, layer_p):
        hd = cfg.hd
        hin = L.rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        q = hin @ layer_p["wq"]
        k = hin @ layer_p["wk"]
        v = hin @ layer_p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + layer_p["bq"], k + layer_p["bk"], v + layer_p["bv"]
        q = q.reshape(B, S, cfg.n_heads, hd)
        k = k.reshape(B, S, cfg.n_kv_heads, hd)
        v = v.reshape(B, S, cfg.n_kv_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_base, cfg.rotary_frac)
        k = L.apply_rope(k, positions, cfg.rope_base, cfg.rotary_frac)
        a = L.gqa_attention(q, k, v, causal=True, window=cfg.window)
        x = x + a.reshape(B, S, cfg.n_heads * hd) @ layer_p["wo"]
        hin = L.rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _ = moe_ffn(layer_p["moe"], hin.reshape(B * S, -1), cfg.moe)
            out = out.reshape(B, S, -1)
        else:
            out = _mlp(layer_p, hin, cfg)
        return x + out, (k.astype(cfg.param_dtype), v.astype(cfg.param_dtype))

    x, (ks, vs) = _scan_layers(body, x, params["layers"], cfg)
    x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["unembed"]
    cache = {"k": ks, "v": vs,
             "len": jnp.full((B,), S, jnp.int32)}
    return logits.astype(jnp.float32), cache


# ----------------------------------------------------------------- decode --
def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or cfg.param_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((batch,), jnp.int32)}


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray,
                cfg: LMConfig) -> Tuple[jnp.ndarray, dict]:
    """One decode step. tokens: (B, 1) -> (logits (B, 1, V), cache')."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.param_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    positions = cache["len"][:, None] + jnp.arange(S)[None]

    def body(carry, inputs):
        x = carry
        layer_p, kc, vc = inputs
        x, _, new_cache = _block(layer_p, x, cfg, positions,
                                 cache_kv=(kc, vc), kv_len=cache["len"])
        return x, new_cache

    # decode never remats (no backward); reuse the scan/unroll switch only
    dec_cfg = dataclasses.replace(cfg, remat=False)
    x, (k_new, v_new) = _scan_layers(
        body, x, (params["layers"], cache["k"], cache["v"]), dec_cfg)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["unembed"]
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + S}
    return logits.astype(jnp.float32), new_cache
