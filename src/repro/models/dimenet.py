"""DimeNet (arXiv:2003.03123) — directional message passing GNN.

Kernel regime: triplet gather (kernel_taxonomy §GNN) — messages live on
edges, and the interaction term couples message m_kj into m_ji through an
angular basis over the triplet (k->j->i). JAX has no sparse primitives for
this; per DESIGN.md the message passing is built on explicit index arrays +
`jax.ops.segment_sum` — that IS the system, not a stub:

  edges:    edge_src[e] = j, edge_dst[e] = i  (message j -> i)
  triplets: trip_kj[t], trip_ji[t] index into the edge list

Basis simplification (documented in DESIGN.md §Arch-applicability): the
original 2-D spherical-Bessel basis is replaced by the separable product
cos(m*theta) x Gaussian-RBF(d), and the bilinear tensor contraction uses the
DimeNet++-style down-projection to n_bilinear channels (arXiv:2011.14115) —
same function family, dramatically cheaper, standard in follow-up work.

Non-geometric graphs (Cora/Reddit/ogbn-products shapes): positions are a
precomputed (N, 3) input provided by the modality-stub `input_specs()`.
Tasks: "node_clf" (citation/products) or "graph_reg" (molecule batches).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.layers import common as L


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 128
    n_out: int = 16              # classes (node_clf) or 1 (graph_reg)
    cutoff: float = 5.0
    task: str = "node_clf"       # "node_clf" | "graph_reg"
    dtype: str = "float32"
    unroll_blocks: bool = False  # cost-analysis mode (see launch/dryrun)
    remat: bool = False          # checkpoint each block (hillclimb B): the
                                 # (T, nb) triplet intermediates of all 6
                                 # blocks otherwise live until backward

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def init_params(cfg: DimeNetConfig, key) -> dict:
    dt = cfg.param_dtype
    H, R = cfg.d_hidden, cfg.n_radial
    SB = cfg.n_spherical * cfg.n_radial
    ks = iter(jax.random.split(key, 12 + 8 * cfg.n_blocks))

    def dense(shape):
        return L.dense_init(next(ks), shape, dtype=dt)

    params = {
        "feat_proj": dense((cfg.d_feat, H)),
        "rbf_emb": dense((R, H)),
        "edge_emb": dense((3 * H, H)),
        "blocks": [],
        "out_proj": dense((H, cfg.n_out)),
    }
    for _ in range(cfg.n_blocks):
        params["blocks"].append({
            "w_msg": dense((H, H)),
            "w_kj_down": dense((H, cfg.n_bilinear)),
            "w_sbf": dense((SB, cfg.n_bilinear)),
            "w_up": dense((cfg.n_bilinear, H)),
            "w_rbf_gate": dense((R, H)),
            "w_self": dense((H, H)),
            "w_out_edge": dense((H, H)),
        })
    # stack blocks for scan
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *params["blocks"])
    return params


def _rbf(d: jnp.ndarray, n_radial: int, cutoff: float) -> jnp.ndarray:
    """Gaussian radial basis with smooth cutoff envelope. (E,) -> (E, R)."""
    centers = jnp.linspace(0.0, cutoff, n_radial)
    width = cutoff / n_radial
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0, 1)) + 1.0)
    return env[:, None] * jnp.exp(-((d[:, None] - centers[None]) / width) ** 2)


def _sbf(theta: jnp.ndarray, d: jnp.ndarray, cfg: DimeNetConfig) -> jnp.ndarray:
    """cos(m*theta) x RBF(d) product basis. (T,) -> (T, S*R)."""
    m = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(theta[:, None] * (m[None] + 1.0))          # (T, S)
    rad = _rbf(d, cfg.n_radial, cfg.cutoff)                  # (T, R)
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        theta.shape[0], cfg.n_spherical * cfg.n_radial)


def forward(params: dict, batch: dict, cfg: DimeNetConfig,
            n_graphs: int = 1) -> jnp.ndarray:
    """batch keys: feats (N, d_feat), pos (N, 3), edge_src/edge_dst (E,),
    trip_kj/trip_ji (T,), node_graph (N,) [graph_reg], with -1 padding on
    edge/triplet arrays. Returns (N, n_out) or (n_graphs, n_out)."""
    feats = batch["feats"].astype(cfg.param_dtype)
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    tkj, tji = batch["trip_kj"], batch["trip_ji"]
    N = feats.shape[0]
    E = src.shape[0]
    e_valid = (src >= 0) & (dst >= 0)
    t_valid = (tkj >= 0) & (tji >= 0)
    srcs = jnp.maximum(src, 0)
    dsts = jnp.maximum(dst, 0)

    h = feats @ params["feat_proj"]                           # (N, H)

    vec = pos[dsts] - pos[srcs]                               # (E, 3)
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = _rbf(dist, cfg.n_radial, cfg.cutoff).astype(cfg.param_dtype)

    m = jnp.concatenate(
        [h[srcs], h[dsts], rbf @ params["rbf_emb"]], axis=-1)
    m = jax.nn.silu(m @ params["edge_emb"])                   # (E, H)
    m = jnp.where(e_valid[:, None], m, 0.0)

    # triplet geometry: angle at j between (k->j) and (j->i)
    tkjs = jnp.maximum(tkj, 0)
    tjis = jnp.maximum(tji, 0)
    v_kj = vec[tkjs]
    v_ji = vec[tjis]
    cosang = jnp.sum(v_kj * v_ji, -1) / (
        jnp.linalg.norm(v_kj + 1e-12, axis=-1)
        * jnp.linalg.norm(v_ji + 1e-12, axis=-1) + 1e-12)
    theta = jnp.arccos(jnp.clip(cosang, -1 + 1e-6, 1 - 1e-6))
    sbf = _sbf(theta, dist[tkjs], cfg).astype(cfg.param_dtype)  # (T, S*R)
    sbf = jnp.where(t_valid[:, None], sbf, 0.0)

    node_out = jnp.zeros((N, cfg.d_hidden), cfg.param_dtype)

    def block(carry, bp):
        m, node_out = carry
        # directional interaction: m_kj down-projected, gated by the
        # angular basis, aggregated onto edge ji (triplet segment-sum).
        a = (m @ bp["w_kj_down"])[tkjs] * (sbf @ bp["w_sbf"])   # (T, nb)
        a = jnp.where(t_valid[:, None], a, 0.0)
        agg = jax.ops.segment_sum(a, tjis, num_segments=E)      # (E, nb)
        upd = jax.nn.silu(m @ bp["w_msg"]) \
            + (agg @ bp["w_up"]) * (rbf @ bp["w_rbf_gate"])
        m_new = jax.nn.silu(upd @ bp["w_self"])
        m_new = jnp.where(e_valid[:, None], m_new, 0.0)
        # per-block output: scatter edge messages to destination nodes
        eo = m_new @ bp["w_out_edge"]
        node_out = node_out + jax.ops.segment_sum(
            jnp.where(e_valid[:, None], eo, 0.0), dsts, num_segments=N)
        return (m_new, node_out), None

    block_fn = jax.checkpoint(block) if cfg.remat else block
    if cfg.unroll_blocks:
        carry = (m, node_out)
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda x: x[i], params["blocks"])
            carry, _ = block_fn(carry, bp)
        m, node_out = carry
    else:
        (m, node_out), _ = jax.lax.scan(block_fn, (m, node_out),
                                        params["blocks"])

    out = jax.nn.silu(node_out) @ params["out_proj"]          # (N, n_out)
    if cfg.task == "graph_reg":
        out = jax.ops.segment_sum(out, batch["node_graph"],
                                  num_segments=n_graphs)
    return out.astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: DimeNetConfig,
            n_graphs: int = 1) -> Tuple:
    out = forward(params, batch, cfg, n_graphs=n_graphs)
    if cfg.task == "node_clf":
        labels = batch["labels"]                              # (N,), -1 ignore
        mask = labels >= 0
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                                   axis=-1)[:, 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    else:
        target = batch["targets"]                             # (G,)
        loss = jnp.mean((out[:, 0] - target) ** 2)
    return loss, {"loss": loss}
