"""Batch-serving engine for KBest indexes (DESIGN.md §11).

    from repro.serve import SearchEngine, Request, serve_loop

`SearchEngine(index)` turns a built `KBest` into a serving endpoint:
incoming batches are padded to a small ladder of power-of-two shape
buckets and dispatched through a compile cache keyed on
(bucket, SearchConfig, index_type, quant), so variable-size request
traffic never re-traces XLA. `serve_loop` drains a queue of
heterogeneous `Request`s — mixed batch sizes, mixed k, graph and IVF
engines side by side — with true served-count accounting.
"""
from repro.serve.engine import (EngineStats, SearchEngine, bucket_ladder,
                                bucket_for)
from repro.serve.scheduler import (Request, RequestResult, ServeReport,
                                   serve_loop)

__all__ = [
    "SearchEngine", "EngineStats", "bucket_for", "bucket_ladder",
    "Request", "RequestResult", "ServeReport", "serve_loop",
]
