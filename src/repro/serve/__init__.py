"""Batch-serving engine for KBest indexes (DESIGN.md §11, §17).

    from repro.serve import SearchEngine, Request, serve_loop

`SearchEngine(index)` turns a built `KBest` into a serving endpoint:
incoming batches are padded to a small ladder of power-of-two shape
buckets and dispatched through a compile cache keyed on
(bucket, SearchConfig, index_type, quant), so variable-size request
traffic never re-traces XLA. `serve_loop` drains a queue of
heterogeneous `Request`s — mixed batch sizes, mixed k, graph and IVF
engines side by side — with true served-count accounting, and owns the
overload story: deadline admission control (`Request.deadline_ms` +
`LatencyModel`), bounded-queue shedding, graceful degradation down a
pre-tuned SearchConfig ladder (`DegradePolicy`), and a per-request error
boundary. `serve.faults` is the matching fault-injection harness.
"""
from repro.serve.degrade import DegradePolicy, LatencyModel
from repro.serve.engine import (EngineStats, SearchEngine, bucket_ladder,
                                bucket_for, percentiles)
from repro.serve.faults import EngineFault, FaultInjector, InjectedCrash
from repro.serve.scheduler import (Request, RequestResult, ServeReport,
                                   STATUS_FAILED, STATUS_OK, STATUS_REJECTED,
                                   STATUS_SHED, serve_loop)

__all__ = [
    "SearchEngine", "EngineStats", "bucket_for", "bucket_ladder",
    "percentiles",
    "Request", "RequestResult", "ServeReport", "serve_loop",
    "STATUS_OK", "STATUS_REJECTED", "STATUS_SHED", "STATUS_FAILED",
    "DegradePolicy", "LatencyModel",
    "FaultInjector", "EngineFault", "InjectedCrash",
]
