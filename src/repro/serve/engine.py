"""SearchEngine — shape-bucketed, compile-cached serving facade (DESIGN.md §11).

The paper's deployment scenario is a service absorbing tens of millions of
queries per day. For a JIT-compiled search stack the dominant avoidable cost
under variable-size traffic is re-tracing: every new batch shape (and every
`SearchConfig` tweak) is a fresh XLA compile, orders of magnitude slower
than the search itself. The engine removes that cost structurally:

  1. **Shape buckets.** Incoming batches are padded up to the next
     power-of-two bucket (clamped to [min_bucket, max_bucket]); batches
     larger than max_bucket are split. A handful of buckets covers any
     traffic mix, so the set of compiled programs is small and bounded.
  2. **Padded lanes are (nearly) free.** Padding rides
     `KBest.search_padded`: graph-index padded rows enter the lockstep
     traversal inactive (core.search's `active` mask — the same mechanism
     that idles early-terminated queries), so they cost no distance
     computations; IVF padded lanes still run the dense ADC scan (no loop
     to idle) but are bounded by one bucket step of slack. Valid rows are
     bit-identical to an unpadded `index.search` either way.
  3. **Compile cache.** Compiled callables are cached on
     (bucket, SearchConfig, index_type, quant_kind, n_shards) — the last
     component is the mesh shape of a sharded index (`ShardedKBest` serves
     through the same facade; its P unrolled shard searches + merge are one
     XLA program per bucket). `n_traces` counts
     actual traces (a Python side effect at trace time), which is both the
     serving telemetry and the regression guard: serving many batch sizes
     under one bucket must trace exactly once.
  4. **Telemetry.** Each call records wall latency, per-query distance
     counts, and early-termination fires; `stats()` folds them into an
     `EngineStats` snapshot (p50/p95/p99, dists/query, ET fire rate, and
     recall when ground truth is supplied).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.index import KBest
from repro.core.types import SearchConfig


def bucket_for(q: int, min_bucket: int = 8, max_bucket: int = 256) -> int:
    """Smallest power-of-two >= q, clamped to [min_bucket, max_bucket]."""
    assert q >= 1, q
    b = 1 << (q - 1).bit_length()
    return max(min_bucket, min(b, max_bucket))


def bucket_ladder(min_bucket: int = 8, max_bucket: int = 256) -> Tuple[int, ...]:
    """All buckets the engine can emit, ascending."""
    out = []
    b = max(1, min_bucket)
    while b < max_bucket:
        out.append(b)
        b <<= 1
    out.append(max_bucket)
    return tuple(out)


def percentiles(values) -> Tuple[float, float, float]:
    """(p50, p95, p99) with the empty-history case guarded: percentile
    telemetry is read before traffic arrives and after drains where every
    request was rejected/shed, and np.percentile([]) raises."""
    arr = np.asarray(list(values), np.float64)
    if arr.size == 0:
        return 0.0, 0.0, 0.0
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 95)),
            float(np.percentile(arr, 99)))


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Telemetry snapshot over every call since construction/reset."""

    n_requests: int            # compiled calls served (post-coalescing)
    n_queries: int             # TRUE query count (padding excluded)
    n_traces: int              # XLA traces of the underlying search
    cache_hits: int
    cache_misses: int
    lat_p50_ms: float          # per-call wall latency percentiles
    lat_p95_ms: float
    lat_p99_ms: float
    mean_lat_ms: float
    dists_per_query: float     # mean over valid lanes (cross-family units)
    et_fire_rate: float        # fraction of valid lanes that early-terminated
    recall_at_k: Optional[float]   # only when gt_ids were supplied
    # ---- overload telemetry (DESIGN.md §17; fed by serve_loop) ----
    n_rejected: int = 0        # deadline-infeasible at admission
    n_shed: int = 0            # dropped at a full bounded queue
    n_failed: int = 0          # dispatch raised; failed its own result
    deadline_miss_rate: float = 0.0   # served-late / deadline-carrying
    degrade_occupancy: Tuple[Tuple[int, int], ...] = ()  # (level, dispatches)

    def summary(self) -> str:
        rec = ("-" if self.recall_at_k is None
               else f"{self.recall_at_k:.3f}")
        return (f"requests={self.n_requests} queries={self.n_queries} "
                f"traces={self.n_traces} "
                f"cache={self.cache_hits}h/{self.cache_misses}m | "
                f"lat p50={self.lat_p50_ms:.2f} p95={self.lat_p95_ms:.2f} "
                f"p99={self.lat_p99_ms:.2f} ms | "
                f"dists/q={self.dists_per_query:.0f} "
                f"et_rate={self.et_fire_rate:.2f} recall={rec} | "
                f"rej={self.n_rejected} shed={self.n_shed} "
                f"fail={self.n_failed} "
                f"miss={self.deadline_miss_rate:.2f}")


class SearchEngine:
    """Serving facade over one built index — KBest (graph or IVF) or a
    ShardedKBest mesh (anything exposing config / db / _resolve_cfg /
    search_padded)."""

    def __init__(self, index: KBest, *, min_bucket: int = 8,
                 max_bucket: int = 256, name: str = "default"):
        assert index.db is not None, "serve a BUILT index (call add() first)"
        assert min_bucket >= 1 and max_bucket >= min_bucket
        # non-power-of-two bounds would make bucket_ladder (warmup) and
        # bucket_for (dispatch) disagree, so warmed traffic could re-trace
        assert min_bucket & (min_bucket - 1) == 0, min_bucket
        assert max_bucket & (max_bucket - 1) == 0, max_bucket
        self.index = index
        self.name = name
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self._cache: Dict[tuple, callable] = {}
        # telemetry accumulators
        self.n_traces = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self._lat_ms: list = []
        self._n_queries = 0
        self._sum_dists = 0
        self._sum_et = 0
        self._gt_hits = 0.0
        self._gt_queries = 0
        # overload telemetry (DESIGN.md §17), fed by serve_loop's note_*
        self._n_rejected = 0
        self._n_shed = 0
        self._n_failed = 0
        self._n_deadline = 0
        self._n_deadline_missed = 0
        self._degrade_occ: Dict[int, int] = {}

    # ------------------------------------------------------------- compile
    def _cache_key(self, bucket: int, scfg: SearchConfig) -> tuple:
        # n_shards is the flat mesh shape of a sharded index (DESIGN.md
        # §12): a re-sharded index is a different XLA program (P unrolled
        # shard searches + merge), so it must be a different cache entry.
        # scfg is the WHOLE frozen SearchConfig, so traversal-shape knobs —
        # beam_width (W expansions/iteration unroll the ET scan W times,
        # DESIGN.md §2), batch_B (chunked distance calls), visited_mode —
        # key distinct programs by construction (tests/test_beam.py pins
        # the beam_width case).
        cfg = self.index.config
        return (bucket, scfg, cfg.index_type, cfg.quant.kind, cfg.n_shards)

    def _compiled(self, bucket: int, scfg: SearchConfig):
        key = self._cache_key(bucket, scfg)
        fn = self._cache.get(key)
        if fn is None:
            self.cache_misses += 1
            index = self.index

            def run(q, mask):
                # Python side effect: executes once per XLA trace, never on
                # cached executions — this IS the trace counter.
                self.n_traces += 1
                return index.search_padded(q, mask, search_cfg=scfg,
                                           with_stats=True)

            fn = jax.jit(run)
            self._cache[key] = fn
        else:
            self.cache_hits += 1
        return fn

    def warmup(self, batch_sizes: Optional[Sequence[int]] = None,
               k: Optional[int] = None,
               search_cfg: Optional[SearchConfig] = None) -> int:
        """Precompile the buckets covering `batch_sizes` (default: the whole
        ladder) for one SearchConfig. Returns the number of fresh traces."""
        scfg = self.index._resolve_cfg(k, search_cfg)
        if batch_sizes is None:
            buckets = bucket_ladder(self.min_bucket, self.max_bucket)
        else:
            buckets = sorted({bucket_for(b, self.min_bucket, self.max_bucket)
                              for b in batch_sizes})
        before = self.n_traces
        d = self.index.db.shape[1]
        for b in buckets:
            q = np.zeros((b, d), np.float32)
            mask = np.zeros((b,), bool)
            mask[0] = True     # one live lane: exercise the real loop body
            out = self._compiled(b, scfg)(q, mask)
            jax.block_until_ready(out)
        return self.n_traces - before

    # -------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: Optional[int] = None,
               search_cfg: Optional[SearchConfig] = None,
               gt_ids: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Serve one request batch. queries: (Q, d), any Q >= 1.

        Pads to the shape bucket, dispatches through the compile cache and
        returns exactly (Q, k) results. Batches beyond max_bucket are split
        into max_bucket chunks. When gt_ids (Q, >=k) is given, recall@k is
        folded into the engine telemetry with the TRUE served count as the
        denominator.
        """
        queries = np.asarray(queries, np.float32)
        assert queries.ndim == 2, queries.shape
        Q = queries.shape[0]
        scfg = self.index._resolve_cfg(k, search_cfg)
        if Q > self.max_bucket:
            parts = [self.search(queries[s:s + self.max_bucket],
                                 search_cfg=scfg,
                                 gt_ids=None if gt_ids is None
                                 else gt_ids[s:s + self.max_bucket])
                     for s in range(0, Q, self.max_bucket)]
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))

        bucket = bucket_for(Q, self.min_bucket, self.max_bucket)
        qp = np.zeros((bucket, queries.shape[1]), np.float32)
        qp[:Q] = queries
        mask = np.zeros((bucket,), bool)
        mask[:Q] = True

        fn = self._compiled(bucket, scfg)
        t0 = time.perf_counter()
        dists, ids, stats = fn(qp, mask)
        jax.block_until_ready((dists, ids))
        dt_ms = (time.perf_counter() - t0) * 1e3

        self._lat_ms.append(dt_ms)
        self._n_queries += Q
        self._sum_dists += int(np.asarray(stats.n_dist).sum())
        self._sum_et += int(np.asarray(stats.early_terminated).sum())

        dists = np.asarray(dists)[:Q]
        ids = np.asarray(ids)[:Q]
        if gt_ids is not None:
            from repro.data.vectors import recall_at_k
            self._gt_hits += recall_at_k(ids, np.asarray(gt_ids)[:Q],
                                         scfg.k) * Q
            self._gt_queries += Q
        return dists, ids

    # ----------------------------------------------------------- telemetry
    def note_rejected(self, n: int = 1) -> None:
        self._n_rejected += n

    def note_shed(self, n: int = 1) -> None:
        self._n_shed += n

    def note_failed(self, n: int = 1) -> None:
        self._n_failed += n

    def note_deadline(self, missed: bool) -> None:
        """One served deadline-carrying request: hit or miss."""
        self._n_deadline += 1
        self._n_deadline_missed += int(missed)

    def note_degrade(self, level: int) -> None:
        """One dispatch served at this degrade-ladder level."""
        self._degrade_occ[level] = self._degrade_occ.get(level, 0) + 1

    def stats(self) -> EngineStats:
        p50, p95, p99 = percentiles(self._lat_ms)
        lat = np.asarray(self._lat_ms, np.float64)
        nq = max(self._n_queries, 1)
        return EngineStats(
            n_requests=lat.size,
            n_queries=self._n_queries,
            n_traces=self.n_traces,
            cache_hits=self.cache_hits,
            cache_misses=self.cache_misses,
            lat_p50_ms=p50,
            lat_p95_ms=p95,
            lat_p99_ms=p99,
            mean_lat_ms=float(lat.mean()) if lat.size else 0.0,
            dists_per_query=self._sum_dists / nq,
            et_fire_rate=self._sum_et / nq,
            recall_at_k=(self._gt_hits / self._gt_queries
                         if self._gt_queries else None),
            n_rejected=self._n_rejected,
            n_shed=self._n_shed,
            n_failed=self._n_failed,
            deadline_miss_rate=(self._n_deadline_missed / self._n_deadline
                                if self._n_deadline else 0.0),
            degrade_occupancy=tuple(sorted(self._degrade_occ.items())),
        )

    def reset_stats(self) -> None:
        """Clear telemetry; the compile cache (and n_traces) is kept —
        traces are a property of the cache, not of a measurement window."""
        self._lat_ms = []
        self._n_queries = 0
        self._sum_dists = 0
        self._sum_et = 0
        self._gt_hits = 0.0
        self._gt_queries = 0
        self._n_rejected = 0
        self._n_shed = 0
        self._n_failed = 0
        self._n_deadline = 0
        self._n_deadline_missed = 0
        self._degrade_occ = {}
