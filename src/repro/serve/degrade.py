"""Graceful degradation + calibrated latency prediction (DESIGN.md §17).

Under sustained overload a serving tier has three options: queue without
bound (latency explodes), drop requests (goodput craters), or serve
cheaper answers. KBest's accuracy/latency knobs (nprobe, L,
rescore_factor — the KScaNN-style trade the tuner sweeps) make the third
option principled: `DegradePolicy` walks a pre-tuned ladder of
SearchConfigs (configs.kbest.degrade_ladder) downward while the observed
queue delay sits above a high watermark, and back up once it falls below
the low watermark. Hysteresis (watermark band + `patience` consecutive
observations) prevents rung flapping at the boundary.

`LatencyModel` is the admission controller's ŝ: the static cost model's
predicted batch seconds (analysis.cost.predict_service_s — correct
ORDERING across configs/buckets, arbitrary absolute scale) multiplied by
an EWMA-calibrated measured/predicted ratio per (engine, SearchConfig,
bucket) key, with a global-ratio fallback so unseen keys borrow the
machine's scale instead of trusting the roofline constants. The
admission rule in serve_loop is then

    admit  iff  t_start + slack * ŝ(engine, cfg, bucket) <= t_arrival + D

with D the request deadline and `slack` a safety factor absorbing
prediction noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.types import SearchConfig
from repro.serve.engine import SearchEngine, bucket_for


@dataclasses.dataclass
class DegradePolicy:
    """Queue-delay-watermark ladder walker. Rung 0 is full quality; every
    further rung is a strictly cheaper standalone SearchConfig
    (tests/test_degrade.py pins validity + cost monotonicity)."""

    ladder: Tuple[SearchConfig, ...]
    high_ms: float = 50.0        # sustained delay above this: step down
    low_ms: float = 10.0         # sustained delay below this: step up
    patience: int = 3            # consecutive observations per transition

    def __post_init__(self):
        assert self.ladder, "need at least one rung (the base config)"
        assert self.low_ms <= self.high_ms, \
            f"watermarks inverted: low_ms={self.low_ms} > high_ms={self.high_ms}"
        assert self.patience >= 1, "patience must be >= 1 observation"
        self.level = 0
        self.transitions: List[Tuple[int, int, int]] = []  # (obs#, from, to)
        self.occupancy: Dict[int, int] = {}
        self._n_obs = 0
        self._over = 0
        self._under = 0

    def observe(self, queue_delay_ms: float) -> int:
        """Feed one pre-dispatch queue-delay observation; returns the level
        to serve at. Transitions need `patience` CONSECUTIVE observations
        past a watermark; the band between the watermarks holds the level
        (hysteresis, so a delay oscillating around one threshold cannot
        flap the rung)."""
        self._n_obs += 1
        if queue_delay_ms > self.high_ms:
            self._over += 1
            self._under = 0
        elif queue_delay_ms < self.low_ms:
            self._under += 1
            self._over = 0
        else:
            self._over = 0
            self._under = 0
        if self._over >= self.patience and self.level < len(self.ladder) - 1:
            self.transitions.append((self._n_obs, self.level, self.level + 1))
            self.level += 1
            self._over = 0
        elif self._under >= self.patience and self.level > 0:
            self.transitions.append((self._n_obs, self.level, self.level - 1))
            self.level -= 1
            self._under = 0
        self.occupancy[self.level] = self.occupancy.get(self.level, 0) + 1
        return self.level

    def apply(self, scfg: SearchConfig) -> SearchConfig:
        """Resolve the config to serve at the current level: rung 0 keeps
        the request's own config untouched; deeper rungs substitute the
        rung's knobs but preserve the request's k (a degraded answer still
        has the asked-for shape)."""
        if self.level == 0:
            return scfg
        rung = self.ladder[self.level]
        if rung.k == scfg.k:
            return rung
        return dataclasses.replace(rung, k=scfg.k, L=max(rung.L, scfg.k))


class LatencyModel:
    """EWMA-calibrated per-(engine, config, bucket) service-time model."""

    def __init__(self, alpha: float = 0.3, slack: float = 1.2):
        assert 0.0 < alpha <= 1.0 and slack >= 1.0
        self.alpha = alpha          # EWMA weight of the newest observation
        self.slack = slack          # admission safety factor on ŝ
        self._ratio: Dict[tuple, float] = {}
        self._global: Optional[float] = None

    def _key(self, engine: SearchEngine, scfg: SearchConfig,
             rows: int) -> tuple:
        b = bucket_for(max(rows, 1), engine.min_bucket, engine.max_bucket)
        return (engine.name, scfg, b)

    def _prior_ms(self, engine: SearchEngine, scfg: SearchConfig,
                  rows: int) -> float:
        from repro.analysis.cost import predict_service_s
        b = bucket_for(max(rows, 1), engine.min_bucket, engine.max_bucket)
        n = int(engine.index.db.shape[0])
        return max(predict_service_s(engine.index.config, scfg,
                                     Q=b, n=n) * 1e3, 1e-9)

    @property
    def calibrated(self) -> bool:
        return self._global is not None

    def predict_ms(self, engine: SearchEngine, scfg: SearchConfig,
                   rows: int) -> float:
        """ŝ in milliseconds: cost-model prior x calibrated ratio (per-key
        if seen, global otherwise, 1.0 before any observation)."""
        prior = self._prior_ms(engine, scfg, rows)
        ratio = self._ratio.get(self._key(engine, scfg, rows), self._global)
        return prior * (ratio if ratio is not None else 1.0)

    def observe(self, engine: SearchEngine, scfg: SearchConfig, rows: int,
                measured_ms: float) -> None:
        """Fold one measured dispatch into the per-key and global EWMAs."""
        ratio = measured_ms / self._prior_ms(engine, scfg, rows)
        key = self._key(engine, scfg, rows)
        prev = self._ratio.get(key)
        self._ratio[key] = ratio if prev is None else \
            (1 - self.alpha) * prev + self.alpha * ratio
        self._global = ratio if self._global is None else \
            (1 - self.alpha) * self._global + self.alpha * ratio
