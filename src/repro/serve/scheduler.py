"""Request scheduler: coalesce + dispatch heterogeneous request traffic.

`serve_loop` drains a FIFO of `Request`s that may differ in batch size, k,
SearchConfig, and even target index family (graph and IVF engines side by
side). Consecutive requests that share a (engine, resolved SearchConfig)
key are coalesced into one padded bucket batch — small requests ride the
same compiled program and the same lockstep dispatch, which is exactly the
batching economics of the paper's serving scenario — and the results are
sliced back per request.

Accounting is per TRUE query: a request of 22 queries coalesced into a
64-bucket contributes 22 to the served count and its recall denominator,
never the padded size (the historical serve_ann bug: counting
`ceil`-batches * batch_size over a partial final batch overstates served
queries and understates recall).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.types import SearchConfig
from repro.serve.engine import EngineStats, SearchEngine


@dataclasses.dataclass
class Request:
    """One serving request: a query batch plus per-request knobs."""

    queries: np.ndarray                      # (Q, d) float32
    k: Optional[int] = None                  # None => engine's config k
    search_cfg: Optional[SearchConfig] = None
    engine: str = "default"                  # routing key into the engine map
    gt_ids: Optional[np.ndarray] = None      # (Q, >=k) optional ground truth
    request_id: int = -1                     # filled by serve_loop if -1

    @property
    def n_queries(self) -> int:
        return int(np.asarray(self.queries).shape[0])


@dataclasses.dataclass
class RequestResult:
    request_id: int
    engine: str
    dists: np.ndarray          # (Q, k)
    ids: np.ndarray            # (Q, k)
    n_served: int              # TRUE query count for this request
    latency_ms: float          # wall time of the (possibly shared) dispatch
    recall: Optional[float]    # only when the request carried gt_ids


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one serve_loop drain."""

    results: List[RequestResult]
    n_requests: int
    n_served: int                          # sum of TRUE per-request counts
    n_dispatches: int                      # compiled calls (post-coalescing)
    recall_at_k: Optional[float]           # served-count-weighted
    lat_p50_ms: float
    lat_p95_ms: float
    lat_p99_ms: float
    engine_stats: Dict[str, EngineStats]

    def summary(self) -> str:
        rec = "-" if self.recall_at_k is None else f"{self.recall_at_k:.3f}"
        return (f"served {self.n_served} queries in {self.n_requests} "
                f"requests ({self.n_dispatches} dispatches) | "
                f"recall={rec} | lat p50={self.lat_p50_ms:.2f} "
                f"p95={self.lat_p95_ms:.2f} p99={self.lat_p99_ms:.2f} ms")


def _coalesce_key(engines: Dict[str, SearchEngine], r: Request) -> tuple:
    eng = engines[r.engine]
    return (r.engine, eng.index._resolve_cfg(r.k, r.search_cfg))


def serve_loop(engines: Union[SearchEngine, Dict[str, SearchEngine]],
               requests: Sequence[Request], *,
               coalesce: bool = True) -> ServeReport:
    """Drain `requests` (FIFO) through the engine map and return the report.

    With coalesce=True, maximal runs of CONSECUTIVE requests sharing a
    coalesce key are packed into one dispatch, capped at the engine's
    max_bucket rows (FIFO order is preserved — the scheduler never reorders
    across requests, so tail latency stays honest under mixed traffic).
    """
    if isinstance(engines, SearchEngine):
        engines = {engines.name: engines}
    q = deque(requests)
    results: List[RequestResult] = []
    next_id = 0
    n_dispatches = 0

    while q:
        group = [q.popleft()]
        if group[0].request_id < 0:
            group[0].request_id = next_id
        next_id = max(next_id, group[0].request_id) + 1
        eng = engines[group[0].engine]
        key = _coalesce_key(engines, group[0])
        rows = group[0].n_queries
        while (coalesce and q and rows < eng.max_bucket
               and _coalesce_key(engines, q[0]) == key
               and rows + q[0].n_queries <= eng.max_bucket):
            r = q.popleft()
            if r.request_id < 0:
                r.request_id = next_id
            next_id = max(next_id, r.request_id) + 1
            rows += r.n_queries
            group.append(r)

        scfg = key[1]
        batch = np.concatenate([np.asarray(r.queries, np.float32)
                                for r in group], axis=0)
        # forward ground truth into the engine telemetry when the whole
        # group carries it (same column count), so per-engine
        # EngineStats.recall_at_k is populated, not just the report's
        gts = [r.gt_ids for r in group]
        gt = None
        if all(g is not None for g in gts):
            cols = {np.asarray(g).shape[1] for g in gts}
            if len(cols) == 1:
                gt = np.concatenate([np.asarray(g) for g in gts], axis=0)
        t0 = time.perf_counter()
        dists, ids = eng.search(batch, search_cfg=scfg, gt_ids=gt)
        dt_ms = (time.perf_counter() - t0) * 1e3
        n_dispatches += 1

        s = 0
        for r in group:
            e = s + r.n_queries
            rec = None
            if r.gt_ids is not None:
                from repro.data.vectors import recall_at_k
                rec = recall_at_k(ids[s:e], np.asarray(r.gt_ids), scfg.k)
            results.append(RequestResult(
                request_id=r.request_id, engine=r.engine,
                dists=dists[s:e], ids=ids[s:e], n_served=r.n_queries,
                latency_ms=dt_ms, recall=rec))
            s = e

    n_served = sum(r.n_served for r in results)
    with_gt = [(r.recall, r.n_served) for r in results if r.recall is not None]
    recall = (sum(rc * ns for rc, ns in with_gt)
              / max(sum(ns for _, ns in with_gt), 1)) if with_gt else None
    lat = np.asarray([r.latency_ms for r in results], np.float64)
    have = lat.size > 0
    return ServeReport(
        results=results,
        n_requests=len(results),
        n_served=n_served,
        n_dispatches=n_dispatches,
        recall_at_k=recall,
        lat_p50_ms=float(np.percentile(lat, 50)) if have else 0.0,
        lat_p95_ms=float(np.percentile(lat, 95)) if have else 0.0,
        lat_p99_ms=float(np.percentile(lat, 99)) if have else 0.0,
        engine_stats={name: e.stats() for name, e in engines.items()},
    )
