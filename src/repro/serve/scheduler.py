"""Request scheduler: coalesce + dispatch heterogeneous request traffic,
overload-safe (DESIGN.md §11, §17).

`serve_loop` drains a FIFO of `Request`s that may differ in batch size, k,
SearchConfig, and even target index family (graph and IVF engines side by
side). Consecutive requests that share a (engine, resolved SearchConfig)
key are coalesced into one padded bucket batch — small requests ride the
same compiled program and the same lockstep dispatch, which is exactly the
batching economics of the paper's serving scenario — and the results are
sliced back per request.

Accounting is per TRUE query: a request of 22 queries coalesced into a
64-bucket contributes 22 to the served count and its recall denominator,
never the padded size (the historical serve_ann bug: counting
`ceil`-batches * batch_size over a partial final batch overstates served
queries and understates recall).

Overload behavior (all off by default — a plain drain is unchanged):

  admission   — requests carrying `deadline_ms` are REJECTED up front when
                `t_start + slack * ŝ > t_arrival + deadline`, with ŝ the
                calibrated per-(engine, config, bucket) latency model
                (serve.degrade.LatencyModel). Rejecting costs ~nothing and
                beats serving an answer nobody is waiting for.
  bounded queue — `max_queue > 0` sheds arrivals that find that many
                admitted requests still pending (status "shed").
  degradation — a `DegradePolicy` observes the pre-dispatch queue delay
                and swaps in cheaper SearchConfig rungs under sustained
                overload (status stays "ok"; `degrade_level` records the
                rung served).
  error boundary — a dispatch that raises fails ONLY the offending
                request(s): coalesced groups are retried singly so one
                poisoned request cannot take down its batch, let alone the
                loop (status "failed", exception in `error`).

Time is a virtual clock in ms: request `arrival_ms` (monotone
non-decreasing, as produced by an open-loop arrival process) meets the
measured per-dispatch service time, exactly the single-server queue of
benchmarks/serving.py's open loop. All decisions use RELATIVE times only,
so a constant clock skew on arrivals (faults.FaultInjector.skew_ms)
cannot change any outcome.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.types import SearchConfig
from repro.serve.degrade import DegradePolicy, LatencyModel
from repro.serve.engine import EngineStats, SearchEngine, percentiles
from repro.serve.faults import FaultInjector

# RequestResult.status codes
STATUS_OK = "ok"
STATUS_REJECTED = "rejected"   # deadline infeasible at admission
STATUS_SHED = "shed"           # bounded queue full at arrival
STATUS_FAILED = "failed"       # dispatch raised; see .error


@dataclasses.dataclass
class Request:
    """One serving request: a query batch plus per-request knobs."""

    queries: np.ndarray                      # (Q, d) float32
    k: Optional[int] = None                  # None => engine's config k
    search_cfg: Optional[SearchConfig] = None
    engine: str = "default"                  # routing key into the engine map
    gt_ids: Optional[np.ndarray] = None      # (Q, >=k) optional ground truth
    request_id: int = -1                     # filled by serve_loop if -1
    arrival_ms: float = 0.0                  # open-loop arrival (virtual clock)
    deadline_ms: float = 0.0                 # relative deadline; 0 => none

    @property
    def n_queries(self) -> int:
        return int(np.asarray(self.queries).shape[0])


@dataclasses.dataclass
class RequestResult:
    request_id: int
    engine: str
    dists: np.ndarray          # (Q, k); +inf rows when not served
    ids: np.ndarray            # (Q, k); -1 rows when not served
    n_served: int              # TRUE query count; 0 unless status == "ok"
    latency_ms: float          # wall time of the (possibly shared) dispatch
    recall: Optional[float]    # only when the request carried gt_ids
    status: str = STATUS_OK
    error: Optional[str] = None        # repr of the exception when "failed"
    queue_delay_ms: float = 0.0        # dispatch start - arrival
    sojourn_ms: float = 0.0            # finish - arrival (queue + service)
    deadline_missed: bool = False      # served, but past its deadline
    degrade_level: int = 0             # ladder rung this dispatch served at


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one serve_loop drain."""

    results: List[RequestResult]
    n_requests: int
    n_served: int                          # sum of TRUE per-request counts
    n_dispatches: int                      # compiled calls (post-coalescing)
    recall_at_k: Optional[float]           # served-count-weighted
    lat_p50_ms: float                      # service-time percentiles (served)
    lat_p95_ms: float
    lat_p99_ms: float
    engine_stats: Dict[str, EngineStats]
    n_rejected: int = 0
    n_shed: int = 0
    n_failed: int = 0
    n_deadline_missed: int = 0
    sojourn_p50_ms: float = 0.0            # queue + service (served requests)
    sojourn_p95_ms: float = 0.0
    sojourn_p99_ms: float = 0.0
    t_end_ms: float = 0.0                  # virtual makespan of the drain

    def summary(self) -> str:
        rec = "-" if self.recall_at_k is None else f"{self.recall_at_k:.3f}"
        out = (f"served {self.n_served} queries in {self.n_requests} "
               f"requests ({self.n_dispatches} dispatches) | "
               f"recall={rec} | lat p50={self.lat_p50_ms:.2f} "
               f"p95={self.lat_p95_ms:.2f} p99={self.lat_p99_ms:.2f} ms")
        if self.n_rejected or self.n_shed or self.n_failed:
            out += (f" | rej={self.n_rejected} shed={self.n_shed} "
                    f"fail={self.n_failed}")
        return out


def _coalesce_key(engines: Dict[str, SearchEngine], r: Request) -> tuple:
    eng = engines[r.engine]
    return (r.engine, eng.index._resolve_cfg(r.k, r.search_cfg))


def _not_served(r: Request, k: int, status: str, *,
                error: Optional[str] = None,
                queue_delay_ms: float = 0.0) -> RequestResult:
    q = r.n_queries
    return RequestResult(
        request_id=r.request_id, engine=r.engine,
        dists=np.full((q, k), np.inf, np.float32),
        ids=np.full((q, k), -1, np.int32),
        n_served=0, latency_ms=0.0, recall=None, status=status,
        error=error, queue_delay_ms=queue_delay_ms)


def _dispatch(eng: SearchEngine, group: List[Request], scfg: SearchConfig,
              faults: Optional[FaultInjector]):
    """One engine call for a coalesced group; returns (dists, ids, gt, dt_ms).
    Raises whatever the fault injector or the engine raises — the caller's
    error boundary owns attribution."""
    batch = np.concatenate([np.asarray(r.queries, np.float32)
                            for r in group], axis=0)
    # forward ground truth into the engine telemetry when the whole group
    # carries it (same column count), so per-engine EngineStats.recall_at_k
    # is populated, not just the report's
    gts = [r.gt_ids for r in group]
    gt = None
    if all(g is not None for g in gts):
        cols = {np.asarray(g).shape[1] for g in gts}
        if len(cols) == 1:
            gt = np.concatenate([np.asarray(g) for g in gts], axis=0)
    if faults is not None:
        faults.check(group)
    t0 = time.perf_counter()
    dists, ids = eng.search(batch, search_cfg=scfg, gt_ids=gt)
    dt_ms = (time.perf_counter() - t0) * 1e3
    if faults is not None:
        dt_ms += faults.extra_ms(group)
    return dists, ids, dt_ms


def serve_loop(engines: Union[SearchEngine, Dict[str, SearchEngine]],
               requests: Sequence[Request], *,
               coalesce: bool = True,
               max_queue: int = 0,
               admission: Optional[bool] = None,
               latency_model: Optional[LatencyModel] = None,
               degrade: Optional[DegradePolicy] = None,
               faults: Optional[FaultInjector] = None) -> ServeReport:
    """Drain `requests` (FIFO) through the engine map and return the report.

    With coalesce=True, maximal runs of CONSECUTIVE requests sharing a
    coalesce key are packed into one dispatch, capped at the engine's
    max_bucket rows (FIFO order is preserved — the scheduler never reorders
    across requests, so tail latency stays honest under mixed traffic); a
    request can only join a dispatch that starts at or after its arrival.

    admission=None auto-enables deadline admission iff any request carries
    one; pass False to measure the no-policy baseline under deadline
    traffic. max_queue/degrade/faults: see the module docstring.
    """
    if isinstance(engines, SearchEngine):
        engines = {engines.name: engines}
    skew = faults.skew_ms if faults is not None else 0.0

    def arr(r: Request) -> float:
        return r.arrival_ms + skew

    admission_on = (any(r.deadline_ms > 0 for r in requests)
                    if admission is None else bool(admission))
    model = latency_model
    if model is None and admission_on:
        model = LatencyModel()

    q = deque(requests)
    results: List[RequestResult] = []
    finishes: List[float] = []        # virtual finish times of served reqs
    next_id = 0
    n_dispatches = 0
    t_free = 0.0

    def assign_id(r: Request) -> Request:
        nonlocal next_id
        if r.request_id < 0:
            r.request_id = next_id
        next_id = max(next_id, r.request_id) + 1
        return r

    def record_served(group, dists, ids, dt_ms, start, scfg, eng, level):
        """Slice a successful dispatch back per request; returns finish."""
        finish = start + dt_ms
        s = 0
        for r in group:
            e = s + r.n_queries
            rec = None
            if r.gt_ids is not None:
                from repro.data.vectors import recall_at_k
                rec = recall_at_k(ids[s:e], np.asarray(r.gt_ids), scfg.k)
            sojourn = finish - arr(r)
            missed = r.deadline_ms > 0 and sojourn > r.deadline_ms
            if r.deadline_ms > 0:
                eng.note_deadline(missed)
            results.append(RequestResult(
                request_id=r.request_id, engine=r.engine,
                dists=dists[s:e], ids=ids[s:e], n_served=r.n_queries,
                latency_ms=dt_ms, recall=rec, status=STATUS_OK,
                queue_delay_ms=start - arr(r), sojourn_ms=sojourn,
                deadline_missed=missed, degrade_level=level))
            finishes.append(finish)
            s = e
        if degrade is not None:
            eng.note_degrade(level)
        if model is not None:
            model.observe(eng, scfg, sum(r.n_queries for r in group), dt_ms)
        return finish

    while q:
        r0 = assign_id(q.popleft())
        eng = engines[r0.engine]
        key = _coalesce_key(engines, r0)
        base_cfg: SearchConfig = key[1]
        a0 = arr(r0)
        start = max(t_free, a0)

        # ---- bounded queue: shed an arrival that finds it full
        if max_queue > 0 and \
                sum(1 for f in finishes if f > a0) >= max_queue:
            results.append(_not_served(r0, base_cfg.k, STATUS_SHED))
            eng.note_shed()
            continue

        # ---- degradation: observe load, pick the rung this dispatch serves
        level = 0
        scfg = base_cfg
        if degrade is not None:
            level = degrade.observe(start - a0)
            scfg = degrade.apply(base_cfg)

        # ---- admission: reject a deadline the predicted finish busts
        if admission_on and r0.deadline_ms > 0:
            pred = model.slack * model.predict_ms(eng, scfg, r0.n_queries)
            if start + pred > a0 + r0.deadline_ms:
                results.append(_not_served(
                    r0, base_cfg.k, STATUS_REJECTED,
                    queue_delay_ms=start - a0))
                eng.note_rejected()
                continue

        group = [r0]
        rows = r0.n_queries
        while (coalesce and q and rows < eng.max_bucket
               and _coalesce_key(engines, q[0]) == key
               and rows + q[0].n_queries <= eng.max_bucket
               and arr(q[0]) <= start):
            r = assign_id(q.popleft())
            if admission_on and r.deadline_ms > 0:
                pred = model.slack * model.predict_ms(eng, scfg, r.n_queries)
                if start + pred > arr(r) + r.deadline_ms:
                    results.append(_not_served(
                        r, base_cfg.k, STATUS_REJECTED,
                        queue_delay_ms=start - arr(r)))
                    eng.note_rejected()
                    continue
            rows += r.n_queries
            group.append(r)

        try:
            dists, ids, dt_ms = _dispatch(eng, group, scfg, faults)
        except Exception as exc:                      # ---- error boundary
            if len(group) == 1:
                results.append(_not_served(
                    r0, base_cfg.k, STATUS_FAILED, error=repr(exc),
                    queue_delay_ms=start - a0))
                eng.note_failed()
                continue          # a failed dispatch charges no service time
            # un-coalesce: re-dispatch singly so only the poisoned
            # request(s) fail — the group must not share their fate
            t = start
            for r in group:
                try:
                    d1, i1, one_ms = _dispatch(eng, [r], scfg, faults)
                except Exception as exc1:
                    results.append(_not_served(
                        r, base_cfg.k, STATUS_FAILED, error=repr(exc1),
                        queue_delay_ms=t - arr(r)))
                    eng.note_failed()
                    continue
                n_dispatches += 1
                t = record_served([r], d1, i1, one_ms, t, scfg, eng, level)
            t_free = max(t_free, t)
            continue

        n_dispatches += 1
        t_free = record_served(group, dists, ids, dt_ms, start, scfg, eng,
                               level)

    served = [r for r in results if r.status == STATUS_OK]
    n_served = sum(r.n_served for r in served)
    with_gt = [(r.recall, r.n_served) for r in served if r.recall is not None]
    recall = (sum(rc * ns for rc, ns in with_gt)
              / max(sum(ns for _, ns in with_gt), 1)) if with_gt else None
    lat_p50, lat_p95, lat_p99 = percentiles([r.latency_ms for r in served])
    soj_p50, soj_p95, soj_p99 = percentiles([r.sojourn_ms for r in served])
    return ServeReport(
        results=results,
        n_requests=len(results),
        n_served=n_served,
        n_dispatches=n_dispatches,
        recall_at_k=recall,
        lat_p50_ms=lat_p50,
        lat_p95_ms=lat_p95,
        lat_p99_ms=lat_p99,
        engine_stats={name: e.stats() for name, e in engines.items()},
        n_rejected=sum(r.status == STATUS_REJECTED for r in results),
        n_shed=sum(r.status == STATUS_SHED for r in results),
        n_failed=sum(r.status == STATUS_FAILED for r in results),
        n_deadline_missed=sum(r.deadline_missed for r in results),
        sojourn_p50_ms=soj_p50,
        sojourn_p95_ms=soj_p95,
        sojourn_p99_ms=soj_p99,
        t_end_ms=max(finishes) if finishes else 0.0,
    )
