"""Fault injection harness for the serving and persistence tiers
(DESIGN.md §17).

Robustness claims need an adversary: this module is how tests and
`benchmarks/serving.py --overload` manufacture the failures the serving
loop must survive —

  latency spikes    — per-request virtual service-time penalties (the
                      scheduler's clock, not a real sleep), deterministic
                      by request_id, so queue-delay / deadline behavior is
                      reproducible in CI.
  engine exceptions — `poisoned` request_ids make the dispatch raise
                      `EngineFault` inside serve_loop's error boundary;
                      the poisoned request must fail alone.
  clock skew        — a constant offset added to every arrival timestamp;
                      admission decisions use only relative times, so
                      statuses must be skew-invariant (pinned by test).

Persistence crash points ride `core.persist.checkpoint`: `trace_steps()`
records every kill point of a save protocol, `crash_at(step)` kills the
next save at exactly that step with `InjectedCrash`
(tests/test_crashsafe.py runs the full matrix).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterable, List, Set

from repro.core import persist


class EngineFault(RuntimeError):
    """Injected engine-side failure (stands in for OOM, kernel asserts,
    poisoned inputs — anything a dispatch can raise)."""


class InjectedCrash(RuntimeError):
    """Injected kill inside a save protocol step (simulated power loss)."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault plan for one serve_loop drain."""

    latency_spikes: Dict[int, float] = dataclasses.field(default_factory=dict)
    poisoned: Set[int] = dataclasses.field(default_factory=set)
    skew_ms: float = 0.0

    def check(self, group: Iterable) -> None:
        """Raise EngineFault if any request in the dispatch group is
        poisoned — called inside serve_loop's error boundary, before the
        engine runs, so the failure is attributable per request."""
        for r in group:
            if r.request_id in self.poisoned:
                raise EngineFault(
                    f"injected engine failure for request {r.request_id}")

    def extra_ms(self, group: Iterable) -> float:
        """Total virtual service-time penalty for a dispatch group."""
        return float(sum(self.latency_spikes.get(r.request_id, 0.0)
                         for r in group))


# ------------------------------------------------------ persistence kills
@contextlib.contextmanager
def trace_steps(out: List[str]):
    """Record every persist.checkpoint() step name fired inside the block —
    the kill-point enumeration a crash matrix iterates over."""
    def hook(step: str) -> None:
        out.append(step)
    persist.set_crash_hook(hook)
    try:
        yield out
    finally:
        persist.set_crash_hook(None)


@contextlib.contextmanager
def crash_at(step: str):
    """Kill the save running inside the block at the FIRST occurrence of
    `step` (later occurrences run clean, so re-saves inside the same
    block — e.g. restoring a baseline — are unaffected)."""
    fired = [False]

    def hook(s: str) -> None:
        if s == step and not fired[0]:
            fired[0] = True
            raise InjectedCrash(f"injected crash at save step '{step}'")
    persist.set_crash_hook(hook)
    try:
        yield
    finally:
        persist.set_crash_hook(None)
