"""Portability shims for jax APIs that moved between 0.4.x and newer jax.

The code targets the current jax surface (jax.shard_map / jax.set_mesh);
these wrappers let the same call sites run on older lines, where shard_map
lives in jax.experimental and/or still takes check_rep instead of
check_vma. See also launch/mesh.py: mesh_context for set_mesh.
"""
from __future__ import annotations

import inspect

import jax


def shard_map(f, **kwargs):
    """jax.shard_map where available, else the jax.experimental fallback.

    Kwarg translation is keyed on the resolved function's signature, not
    the jax version: the ~0.5-0.6 window exposes top-level jax.shard_map
    that still takes check_rep.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    if "check_vma" in kwargs and "check_vma" not in params:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if kwargs.get("mesh") is None and not hasattr(jax, "set_mesh"):
        # pre-set_mesh jax requires an explicit mesh; recover the ambient
        # one (activated by mesh_context's `with mesh:`) from the
        # resource env
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if env_mesh.empty:
            raise ValueError(
                "shard_map without mesh= needs an active mesh context "
                "(launch.mesh.mesh_context) on this jax version")
        kwargs["mesh"] = env_mesh
    return fn(f, **kwargs)
