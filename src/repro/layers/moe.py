"""Mixture-of-Experts FFN with sort-based dispatch (DESIGN.md §7).

Dense one-hot dispatch einsums (GShard-style) cost O(T·E·C) extra work —
untenable at E=384 (kimi-k2). The sort-based path is O(T·k log(T·k)) for
the permutation plus the unavoidable O(T·k·d·f) expert math:

  router top-k -> flatten (T*k) assignments -> stable-sort by expert ->
  per-expert positions via exclusive-scan of counts -> capacity-drop ->
  scatter token ids into an (E, C) slot buffer -> gather tokens (E, C, d)
  -> batched expert GEMMs -> weighted scatter-add back to (T, d).

Expert parallelism: the (E, ...) leading axis of both the slot buffer and
the expert weights is what the sharding rules map to the mesh's EP axis;
GSPMD then materializes the dispatch/return all-to-alls at the boundary.

Capacity C = ceil(T*k/E * capacity_factor); overflow tokens are dropped
(standard). Aux load-balancing loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.layers.common import act_fn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    gated: bool = True           # SwiGLU experts
    act: str = "silu"
    router_aux_weight: float = 0.01
    # Explicit EP/TP layout constraints (hillclimb A, EXPERIMENTS.md §Perf).
    # The expensive mistakes GSPMD makes without them, observed in the
    # kimi-k2 dry-run HLO:
    #   * the (E*C, d) dispatch buffer (top_k x more rows than tokens!) is
    #     all-gathered to full d for the column-parallel w_in GEMM
    #     (17.5 GiB/layer) — gathering the (T, d) token buffer BEFORE
    #     dispatch duplication is top_k x cheaper;
    #   * the row-parallel w_out partial sums are all-reduced on the
    #     (E, C, d) buffer (18.8 GiB/layer) — reduce-scattering to d-shards
    #     and combining back to tokens in shards defers the all-gather to
    #     the (T, d) residual (0.9 GiB).
    # Empty strings = unconstrained (CPU tests / single-device meshes).
    ep_axis: str = ""            # mesh axis experts are sharded over
    tp_axis: str = ""            # mesh axis expert d_ff is sharded over
    token_axes: tuple = ()       # mesh axes the flat token dim is sharded on
    # explicit-collective dispatch (moe_ffn_shardmap): every collective is
    # hand-placed (all_to_all over EP, psums over TP, final all-gather) —
    # the auto-partitioned path's backward-transpose collectives are
    # unreachable via primal constraints (EXPERIMENTS.md §Perf A3).
    use_shardmap: bool = False
    ep_size: int = 0             # static mesh-axis sizes (shard_map needs
    tp_size: int = 0             # them at trace time)


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff_expert
    s_in = 1.0 / (d_model ** 0.5)
    s_out = 1.0 / (f ** 0.5)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, d_model, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (E, f, d_model)) * s_out).astype(dtype),
    }
    if cfg.gated:
        p["w_gate"] = (jax.random.normal(ks[3], (E, d_model, f)) * s_in).astype(dtype)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_w_in"] = (jax.random.normal(ks[4], (d_model, fs)) * s_in).astype(dtype)
        p["shared_w_gate"] = (jax.random.normal(ks[0], (d_model, fs)) * s_in).astype(dtype)
        p["shared_w_out"] = (jax.random.normal(ks[1], (fs, d_model)) * s_out).astype(dtype)
    return p


def moe_ffn(params: dict, x: jnp.ndarray, cfg: MoEConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, d) flattened tokens -> (out (T, d), aux_loss scalar)."""
    from jax.sharding import PartitionSpec as P

    def cs(t, spec):
        return jax.lax.with_sharding_constraint(t, spec) if cfg.ep_axis else t

    tok = tuple(cfg.token_axes) or None
    # keep d SHARDED over TP through the whole dispatch: the top_k-duplicated
    # buffers then move d/tp-sized slices (the 16x token replication across
    # the TP axis was the dominant collective volume in the baseline HLO)
    x = cs(x, P(tok, cfg.tp_axis or None))
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(T * K / E * cfg.capacity_factor))
    act = act_fn(cfg.act)

    logits = x.astype(jnp.float32) @ params["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, eidx = jax.lax.top_k(probs, K)                            # (T, K)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch eq. 4) ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(frac_tokens * frac_probs)

    # ---- sort-based dispatch ----
    flat_e = eidx.reshape(-1)                                    # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    counts = jax.ops.segment_sum(jnp.ones_like(se, jnp.int32), se,
                                 num_segments=E)                 # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)             # drop -> sentinel

    buf_tok = jnp.full((E * C + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, st, -1))[:E * C]
    tok_valid = buf_tok >= 0
    # NB: the zero literal must carry x.dtype — a bare 0.0 weak-f32 would
    # promote the whole expert pipeline (and its gradients) to f32: 2x the
    # MXU time and 2x the all-reduce bytes (found via the dry-run HLO).
    xe = jnp.where(tok_valid[:, None], x[jnp.maximum(buf_tok, 0)],
                   jnp.zeros((), x.dtype))
    xe = xe.reshape(E, C, d)

    tp = cfg.tp_axis or None
    # pin the dispatch buffer to EP x TP: E on the EP axis, d on the TP
    # axis. d-sharding makes the w_in GEMM a 2-D contraction whose psum is
    # the SMALL (E, C, f/tp) partial, and makes the backward dxe a
    # reduce-scatter instead of an (E, C, d) f32 all-reduce.
    xe = cs(xe, P(cfg.ep_axis or None, None, tp))

    # ---- batched expert GEMMs ----
    # preferred_element_type pins the DOT OUTPUT dtype: GSPMD places the
    # cross-shard partial-sum all-reduce between the dot and any convert,
    # so an f32-preferring dot puts f32 on the wire — observed to double
    # every MoE collective. In-tile MXU accumulation stays f32 regardless.
    pet = dict(preferred_element_type=x.dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_in"], **pet)
    if cfg.gated:
        g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"], **pet)
        h = act(g) * h
    else:
        h = act(h)
    h = cs(h, P(cfg.ep_axis or None, None, tp))
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"], **pet)   # (E, C, d)
    # reduce-scatter the row-parallel partials: d stays sharded over TP
    ye = cs(ye, P(cfg.ep_axis or None, None, tp))

    # ---- weighted combine back to tokens ----
    ye_flat = ye.reshape(E * C, d)
    contrib = jnp.where(keep[:, None],
                        ye_flat[jnp.minimum(slot, E * C - 1)]
                        * sw[:, None].astype(ye_flat.dtype),
                        jnp.zeros((), ye_flat.dtype))
    out = jnp.zeros((T, d), ye_flat.dtype).at[st].add(contrib)
    # combine happened in d-shards; the residual add all-gathers (T, d) —
    # top_k x less wire than gathering the capacity buffer
    out = cs(out, P(tok, tp))

    # ---- shared experts (DeepSeek/Kimi style, always-on) ----
    if "shared_w_in" in params:
        hs = x @ params["shared_w_in"]
        gs = x @ params["shared_w_gate"]
        out = out + (act(gs) * hs) @ params["shared_w_out"]

    return out.astype(x.dtype), aux


# ===========================================================================
# Explicit-collective MoE (hillclimb A, landed): shard_map dispatch
# ===========================================================================
def moe_ffn_shardmap(params: dict, x: jnp.ndarray, cfg: MoEConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """moe_ffn with HAND-PLACED collectives under jax.shard_map.

    Layout (mesh axes ep x tp; weights: w_in/w_gate d-sharded over tp,
    w_out f-sharded over tp; experts sharded over ep — see
    sharding/rules.py lm_param_spec(moe_d_sharded=True)):

      per (r, c) device                                  comm (kimi/layer)
      1. route the local (T_l, d) tokens (replicated math)        —
      2. column c dispatches its T_l/Dt token slice into an
         (E, C_l, d) capacity buffer (sort-based, as moe_ffn)     —
      3. all_to_all over ep: (E, C_l, d) -> (E_l, De*C_l, d)   0.6 GiB
      4. h = xr[:, :, d_c] @ w_in_c  -> psum over tp  (x2 gate) 0.3 GiB
      5. ye = h[:, :, f_c] @ w_out_c -> psum over tp            0.6 GiB
      6. all_to_all back over ep                                0.6 GiB
      7. weighted scatter-combine to (T_s, d); all_gather
         the token slices over tp -> (T_l, d)                   0.9 GiB
                                                     total fwd ~3 GiB
    vs ~90 GiB/layer measured on the auto-partitioned baseline. The
    backward transposes each collective mechanically (a2a<->a2a,
    psum<->identity-broadcast, all_gather<->psum_scatter/reduce).

    Capacity is per (expert, column-slice): C_l = ceil(T_s*K/E * factor).
    Results match moe_ffn exactly when no tokens are dropped (tests).
    """
    from jax.sharding import PartitionSpec as P

    ep, tp = cfg.ep_axis, cfg.tp_axis
    De, Dt = cfg.ep_size, cfg.tp_size
    assert De > 0 and Dt > 0, "set MoEConfig.ep_size/tp_size for shardmap"
    E, K = cfg.n_experts, cfg.top_k
    E_l = E // De
    act = act_fn(cfg.act)
    tok = tuple(cfg.token_axes) or (ep,)

    def block(x_l, router, w_in, w_gate, w_out):
        # x_l (T_l, d) full-d; w_in/w_gate (E_l, d_l, f); w_out (E_l, f_l, d)
        T_l, d = x_l.shape
        T_s = T_l // Dt
        C_l = max(1, int(T_s * K / E * cfg.capacity_factor))
        c = jax.lax.axis_index(tp)
        d_l = w_in.shape[1]
        f = w_in.shape[2]
        f_l = w_out.shape[1]

        # ---- 1. routing (local, exact — router replicated) --------------
        logits = x_l.astype(jnp.float32) @ router            # (T_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        wts, eidx = jax.lax.top_k(probs, K)
        wts = wts / jnp.maximum(jnp.sum(wts, -1, keepdims=True), 1e-9)
        frac_tokens = jnp.mean(
            jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
        aux = cfg.router_aux_weight * E * jnp.sum(
            frac_tokens * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(jax.lax.pmean(aux, ep), tp)

        # ---- 2. column c dispatches its token slice ----------------------
        x_s = jax.lax.dynamic_slice(x_l, (c * T_s, 0), (T_s, d))
        e_s = jax.lax.dynamic_slice(eidx, (c * T_s, 0), (T_s, K))
        w_s = jax.lax.dynamic_slice(wts, (c * T_s, 0), (T_s, K))
        flat_e = e_s.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_s, dtype=jnp.int32), K)
        flat_w = w_s.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jax.ops.segment_sum(jnp.ones_like(se, jnp.int32), se,
                                     num_segments=E)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(T_s * K, dtype=jnp.int32) - starts[se]
        keep = pos < C_l
        slot = jnp.where(keep, se * C_l + pos, E * C_l)
        buf_tok = jnp.full((E * C_l + 1,), -1, jnp.int32).at[slot].set(
            jnp.where(keep, st, -1))[:E * C_l]
        valid = buf_tok >= 0
        xe = jnp.where(valid[:, None], x_s[jnp.maximum(buf_tok, 0)],
                       jnp.zeros((), x_s.dtype)).reshape(E, C_l, d)

        # ---- 3. dispatch all_to_all over EP ------------------------------
        xr = jax.lax.all_to_all(xe, ep, split_axis=0, concat_axis=1,
                                tiled=True)                   # (E_l, De*C_l, d)

        # ---- 4. expert GEMMs ---------------------------------------------
        # Columns hold DISJOINT token slices, so a d-contraction psum over
        # TP would mix different tokens' partials. First a2a over TP trades
        # the d axis for the token axis (every column: ALL tokens, its d_l
        # slice — same bytes), contract, then psum_scatter hands each
        # column back exactly its own token block of the full-f result.
        C_row = xr.shape[1]
        xr = jax.lax.all_to_all(xr, tp, split_axis=2, concat_axis=1,
                                tiled=True)                # (E_l, Dt*C_row, d_l)
        pet = dict(preferred_element_type=x_l.dtype)
        h = jax.lax.psum_scatter(
            jnp.einsum("ecd,edf->ecf", xr, w_in, **pet), tp,
            scatter_dimension=1, tiled=True)               # (E_l, C_row, f)
        if cfg.gated:
            g = jax.lax.psum_scatter(
                jnp.einsum("ecd,edf->ecf", xr, w_gate, **pet), tp,
                scatter_dimension=1, tiled=True)
            h = act(g) * h
        else:
            h = act(h)

        # ---- 5. down-projection: same trade (f <-> tokens) as step 4 -----
        hh = jax.lax.all_to_all(h, tp, split_axis=2, concat_axis=1,
                                tiled=True)                # (E_l, Dt*C_row, f_l)
        ye = jax.lax.psum_scatter(
            jnp.einsum("ecf,efd->ecd", hh, w_out, **pet), tp,
            scatter_dimension=1, tiled=True)               # (E_l, C_row, d)

        # ---- 6. return all_to_all over EP --------------------------------
        yr = jax.lax.all_to_all(ye, ep, split_axis=1, concat_axis=0,
                                tiled=True)                   # (E, C_l, d)

        # ---- 7. weighted combine + reassemble the token axis over TP -----
        yf = yr.reshape(E * C_l, d)
        contrib = jnp.where(keep[:, None],
                            yf[jnp.minimum(slot, E * C_l - 1)]
                            * sw[:, None].astype(yf.dtype),
                            jnp.zeros((), yf.dtype))
        out_s = jnp.zeros((T_s, d), yf.dtype).at[st].add(contrib)
        out_l = jax.lax.all_gather(out_s, tp, axis=0, tiled=True)  # (T_l, d)
        return out_l, aux

    fn = shard_map(
        block,
        in_specs=(P(tok, None), P(), P(ep, tp, None), P(ep, tp, None),
                  P(ep, tp, None)),
        out_specs=(P(tok, None), P()),
        check_vma=False)
    w_gate = params.get("w_gate", params["w_in"])
    out, aux = fn(x, params["router"], params["w_in"], w_gate,
                  params["w_out"])

    if "shared_w_in" in params:
        hs = x @ params["shared_w_in"]
        gs = x @ params["shared_w_gate"]
        out = out + (act(gs) * hs) @ params["shared_w_out"]
    return out.astype(x.dtype), aux
