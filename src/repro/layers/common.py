"""Shared NN building blocks (pure functions over param pytrees).

Models in repro.models are pure JAX: params are nested dicts of arrays, all
layers are functions. Sharding is NOT baked in here — launch/sharding map
param-tree paths to PartitionSpecs (sharding/rules.py), keeping the model
math mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, base: float = 10_000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10_000.0,
               rotary_frac: float = 1.0) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S).

    rotary_frac < 1 rotates only the first rotary_frac*D dims (ChatGLM's
    "2d" RoPE applies rotation to half the head dim, leaving the rest as
    plain channels — rotary_frac=0.5).
    """
    D = x.shape[-1]
    rd = int(D * rotary_frac)
    rd -= rd % 2
    xr, xp = x[..., :rd], x[..., rd:]
    inv = rope_freqs(rd, base)                                    # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, rd/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, rd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rd < D else out


# ------------------------------------------------------------ attention ----
def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool, window: int = 0,
                  q_offset: jnp.ndarray | int = 0,
                  kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Grouped-query attention.

    q: (B, S, Hq, D); k, v: (B, T, Hkv, D) with Hq % Hkv == 0.
    causal: apply causal mask with q positions offset by q_offset (decode).
    window > 0: sliding-window attention (sub-quadratic memory per step
    when combined with chunking; mask-based here).
    kv_len: (B,) valid kv prefix length (decode with preallocated cache).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bshgd,bthd->bhgst", qf, kf) / jnp.sqrt(D)

    # per-example query positions: (B, S)
    off = jnp.broadcast_to(jnp.asarray(q_offset).reshape(-1, 1), (B, 1))
    qpos = off + jnp.arange(S)[None, :]
    kpos = jnp.arange(T)
    mask = jnp.ones((B, S, T), dtype=bool)
    if causal:
        mask &= kpos[None, None, :] <= qpos[:, :, None]
    if window > 0:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    if kv_len is not None:
        mask &= kpos[None, None, :] < kv_len[:, None, None]
    mask = mask[:, None, None]                          # (B, 1, 1, S, T)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, vf)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


# ----------------------------------------------------------------- acts ----
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "tanh": jnp.tanh}[name]


# ------------------------------------------------------------- embedbag ----
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets_or_mask: jnp.ndarray, mode: str = "sum"
                  ) -> jnp.ndarray:
    """EmbeddingBag via take + masked reduce (JAX has no native op —
    DESIGN.md: this IS part of the system, not a stub).

    table: (V, D); ids: (B, A) int32 with -1 padding;
    offsets_or_mask: (B, A) bool validity mask.
    """
    vecs = table[jnp.maximum(ids, 0)]                   # (B, A, D)
    m = offsets_or_mask[..., None].astype(vecs.dtype)
    s = jnp.sum(vecs * m, axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(m, axis=1), 1.0)
        return s / cnt
    if mode == "max":
        neg = jnp.where(offsets_or_mask[..., None], vecs, -jnp.inf)
        return jnp.max(neg, axis=1)
    raise ValueError(mode)


# ----------------------------------------------------------------- init ----
def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)
