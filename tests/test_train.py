"""Training substrate: optimizer correctness, loss descent, checkpointing,
fault tolerance, elastic reshard, gradient compression."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, lm_batches
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train import checkpoint as ck
from repro.train.loop import SimulatedFailure, Trainer, TrainerConfig
from repro.train.optimizer import OptConfig, opt_init, opt_update

TINY = LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                d_ff=128, vocab=128, dtype="float32", remat=False)


def _lfn(params, batch):
    return loss_fn(params, batch, TINY)


def test_adamw_decreases_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt_init(params, cfg)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adafactor_decreases_quadratic():
    cfg = OptConfig(kind="adafactor", lr=0.3, weight_decay=0.0)
    params = {"w": jnp.ones((8, 4)) * 5.0}
    state = opt_init(params, cfg)
    for _ in range(80):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0
    # factored: second moment is rank-1 (vr + vc), much smaller than w
    v = state["v"]["w"]
    assert set(v.keys()) == {"vr", "vc"}
    assert v["vr"].shape == (8,) and v["vc"].shape == (4,)


def test_trainer_loss_decreases(tmp_path):
    p = init_params(TINY, jax.random.PRNGKey(0))
    tr = Trainer(_lfn, OptConfig(lr=1e-3),
                 TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                               log_every=5))
    out = tr.fit(p, Prefetcher(lm_batches(128, 8, 32)), n_steps=40)
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"] - 0.2, h


def test_failure_injection_and_resume(tmp_path):
    p0 = init_params(TINY, jax.random.PRNGKey(0))
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10, fail_at_step=17)
    tr = Trainer(_lfn, OptConfig(lr=1e-3), tc)
    with pytest.raises(SimulatedFailure):
        tr.fit(p0, Prefetcher(lm_batches(128, 8, 32)), n_steps=30)
    # restart resumes from step 10, not step 0
    tr2 = Trainer(_lfn, OptConfig(lr=1e-3),
                  TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10))
    out = tr2.fit(init_params(TINY, jax.random.PRNGKey(0)),
                  Prefetcher(lm_batches(128, 8, 32)), n_steps=30)
    assert out["history"][0]["step"] == 10


def test_checkpoint_atomic_and_pruned(tmp_path):
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, tree, keep_last=2)
    assert ck.latest_step(str(tmp_path)) == 5
    import pathlib
    kept = sorted(pathlib.Path(tmp_path).glob("step_*"))
    assert len(kept) == 2
    back = ck.restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


def test_checkpoint_bit_exact_roundtrip(tmp_path):
    p = init_params(TINY, jax.random.PRNGKey(3))
    opt = opt_init(p, OptConfig())
    ck.save(str(tmp_path), 7, {"params": p, "opt": opt})
    back = ck.restore(str(tmp_path), 7, {"params": p, "opt": opt})
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written with one sharding restores under another mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    p = init_params(TINY, jax.random.PRNGKey(0))
    ck.save(str(tmp_path), 1, {"params": p})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), p)
    back = ck.restore(str(tmp_path), 1, {"params": p}, {"params": sh})
    leaf = jax.tree.leaves(back["params"])[0]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_grad_compression_error_feedback_converges():
    """EF-int8 compressed updates reach the same optimum on a quadratic."""
    from repro.train.compress import compress_decompress, init_residual
    w = jnp.ones((16,)) * 3.0
    res = init_residual({"w": w})
    lr = 0.05
    for _ in range(300):
        g = {"w": 2 * w}
        gq, res = compress_decompress(g, res)
        w = w - lr * gq["w"]
    assert float(jnp.abs(w).max()) < 1e-2


def test_grad_compression_bounded_error():
    from repro.train.compress import compress_decompress, init_residual
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    res = init_residual(g)
    gq, res2 = compress_decompress(g, res)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    err = np.abs(np.asarray(gq["w"] - g["w"]))
    assert err.max() <= scale * 0.5 + 1e-6  # half-bin quantization error
    np.testing.assert_allclose(np.asarray(res2["w"]),
                               np.asarray(g["w"] - gq["w"]), rtol=1e-6)


def test_straggler_detection(tmp_path):
    import time
    p = init_params(TINY, jax.random.PRNGKey(0))
    tr = Trainer(_lfn, OptConfig(lr=1e-3),
                 TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                               straggler_kappa=1.5))
    slow = {"n": 0}
    base = lm_batches(128, 8, 32)

    def gen():
        for i, b in enumerate(base):
            if i == 12:
                time.sleep(1.0)   # inject a straggler step
            yield b
    out = tr.fit(p, gen(), n_steps=16)
    assert out["stragglers"] >= 1
