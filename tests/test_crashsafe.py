"""Crash-safe persistence (DESIGN.md §17): kill the save at EVERY protocol
step and assert load() sees the previous intact index, the new complete
one (only past the final commit), or a clean IndexCorruptError — never a
silently wrong index. Plus direct corruption: truncation, bit flips, torn
sidecars, mixed-generation sharded saves."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs import kbest as kcfg
from repro.core.index import KBest, _meta_path, _npz_path
from repro.core.persist import IndexCorruptError
from repro.core.sharded import ShardedKBest
from repro.serve.faults import InjectedCrash, crash_at, trace_steps

SEED = 7
N = 160


def _build(seed: int) -> KBest:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, 32)).astype(np.float32)
    return KBest(kcfg.smoke_config()).add(x)


def _build_sharded(seed: int) -> ShardedKBest:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, 32)).astype(np.float32)
    return ShardedKBest(kcfg.sharded_smoke_config(2)).add(x)


@pytest.fixture(scope="module")
def old_new():
    return _build(SEED), _build(SEED + 1)


@pytest.fixture(scope="module")
def old_new_sharded():
    return _build_sharded(SEED), _build_sharded(SEED + 1)


def _db(idx) -> np.ndarray:
    if isinstance(idx, ShardedKBest):
        return np.concatenate([np.asarray(s.db) for s in idx.shards])
    return np.asarray(idx.db)


def _steps(save_fn, path) -> list:
    out = []
    with trace_steps(out):
        save_fn(path)
    assert out, "save fired no checkpoints — the crash matrix is empty"
    return out


def _run_matrix(old, new, loader, tmp_path, name):
    """For each kill point: restore the old save, crash the new save at
    that step, and demand load() yields old bytes, new bytes, or a clean
    IndexCorruptError."""
    path = str(tmp_path / name)
    steps = _steps(new.save, str(tmp_path / (name + ".probe")))
    old_db, new_db = _db(old), _db(new)
    saw_error = saw_old = False
    for step in steps:
        old.save(path)                      # reset to a committed baseline
        with crash_at(step):
            with pytest.raises(InjectedCrash):
                new.save(path)
        try:
            got = _db(loader(path))
        except IndexCorruptError:
            saw_error = True
            continue
        is_old = got.shape == old_db.shape and np.array_equal(got, old_db)
        is_new = got.shape == new_db.shape and np.array_equal(got, new_db)
        saw_old |= is_old
        assert is_old or is_new, \
            f"kill at '{step}' loaded a mixed-generation index"
    # the matrix must actually exercise both outcomes, or it proves nothing
    assert saw_old, "no kill point preserved the old index"
    assert saw_error, "no kill point produced a detectable partial save"


def test_crash_matrix_single(old_new, tmp_path):
    old, new = old_new
    _run_matrix(old, new, KBest.load, tmp_path, "idx.npz")


def test_crash_matrix_sharded(old_new_sharded, tmp_path):
    old, new = old_new_sharded
    _run_matrix(old, new, ShardedKBest.load, tmp_path, "mesh")


def test_first_save_crash_leaves_clean_error_or_nothing(old_new, tmp_path):
    """With NO previous save, a mid-save crash must yield FileNotFoundError,
    IndexCorruptError, or (only when the kill lands after the sidecar
    commit) the complete new index — never a partial one."""
    _, new = old_new
    steps = _steps(new.save, str(tmp_path / "probe.npz"))
    for i, step in enumerate(steps):
        path = str(tmp_path / f"fresh{i}.npz")
        with crash_at(step):
            with pytest.raises(InjectedCrash):
                new.save(path)
        try:
            got = KBest.load(path)
        except (FileNotFoundError, IndexCorruptError):
            continue
        assert step == "index.meta.committed", \
            f"kill at pre-commit step '{step}' still loaded"
        assert np.array_equal(np.asarray(got.db), np.asarray(new.db))


def test_truncated_npz_fails_loudly(old_new, tmp_path):
    old, _ = old_new
    path = tmp_path / "t.npz"
    old.save(str(path))
    raw = _npz_path(path).read_bytes()
    _npz_path(path).write_bytes(raw[:len(raw) // 2])
    with pytest.raises(IndexCorruptError):
        KBest.load(str(path))


def test_bitflip_fails_checksum(old_new, tmp_path):
    """A flipped payload byte that still unzips must be caught by the
    per-array crc32 — flip inside the (stored-size-dominant) data region."""
    old, _ = old_new
    path = tmp_path / "b.npz"
    old.save(str(path))
    raw = bytearray(_npz_path(path).read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    _npz_path(path).write_bytes(bytes(raw))
    with pytest.raises(IndexCorruptError):
        KBest.load(str(path))


def test_torn_sidecar_fails_loudly(old_new, tmp_path):
    old, _ = old_new
    path = tmp_path / "s.npz"
    old.save(str(path))
    mp = _meta_path(path)
    mp.write_text(mp.read_text()[:20])      # torn JSON
    with pytest.raises(IndexCorruptError):
        KBest.load(str(path))


def test_legacy_sidecar_without_checksums_still_loads(old_new, tmp_path):
    """Pre-§17 saves carry no "checksums" key: load() skips verification
    instead of rejecting every old artifact on disk."""
    old, _ = old_new
    path = tmp_path / "legacy.npz"
    old.save(str(path))
    meta = json.loads(_meta_path(path).read_text())
    meta.pop("checksums")
    meta.pop("format")
    _meta_path(path).write_text(json.dumps(meta))
    got = KBest.load(str(path))
    assert np.array_equal(np.asarray(got.db), np.asarray(old.db))


def test_mixed_generation_sharded_save_rejected(old_new_sharded, tmp_path):
    """Overwrite shard0 with a different save generation under an
    unchanged manifest: the manifest's sidecar crc32 must catch it."""
    old, new = old_new_sharded
    path = str(tmp_path / "mix")
    old.save(path)
    new.shards[0].save(ShardedKBest._shard_path(path, 0), _label="shard0")
    with pytest.raises(IndexCorruptError):
        ShardedKBest.load(path)


def test_no_stray_tmp_files_after_clean_save(old_new, tmp_path):
    old, _ = old_new
    old.save(str(tmp_path / "clean.npz"))
    assert not list(Path(tmp_path).glob("*.tmp"))
