"""Property-based tests (hypothesis) for the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import queue as qmod

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def _mk_queue(dists, ids, visited):
    order = np.argsort(dists, kind="stable")
    return qmod.Queue(jnp.asarray(dists[order], jnp.float32),
                      jnp.asarray(ids[order], jnp.int32),
                      jnp.asarray(visited[order]))


@given(st.integers(2, 24), st.integers(1, 16), st.integers(0, 2 ** 30))
def test_merge_insert_invariants(L, M, seed):
    r = np.random.default_rng(seed)
    n_filled = r.integers(0, L + 1)
    dists = np.full(L, np.inf, np.float32)
    ids = np.full(L, -1, np.int64)
    dists[:n_filled] = r.normal(size=n_filled).astype(np.float32)
    ids[:n_filled] = r.choice(10_000, size=n_filled, replace=False)
    vis = np.ones(L, bool)
    vis[:n_filled] = r.random(n_filled) < 0.5
    q = _mk_queue(dists, ids, vis)

    nd = r.normal(size=M).astype(np.float32)
    ni = r.integers(-1, 10_000, size=M).astype(np.int32)
    out, best_rank, n_ins = qmod.merge_insert(q, jnp.asarray(nd),
                                              jnp.asarray(ni))
    od, oi = np.asarray(out.dists), np.asarray(out.ids)
    # sorted ascending (comparison, not diff: inf - inf would be nan)
    assert np.all(od[:-1] <= od[1:])
    # no duplicate valid ids
    valid = oi[oi >= 0]
    assert len(valid) == len(set(valid.tolist()))
    # best_rank within [0, L]
    assert 0 <= int(best_rank) <= L
    # the best surviving entry is no worse than before
    assert od[0] <= np.asarray(q.dists)[0] + 1e-6


@given(st.integers(4, 64), st.integers(0, 2 ** 30))
def test_merge_idempotent_on_duplicates(L, seed):
    """Re-inserting the queue's own content must change nothing."""
    r = np.random.default_rng(seed)
    dists = np.sort(r.normal(size=L).astype(np.float32))
    ids = r.choice(100_000, size=L, replace=False).astype(np.int64)
    q = _mk_queue(dists, ids, np.zeros(L, bool))
    out, best_rank, _ = qmod.merge_insert(
        q, jnp.asarray(dists), jnp.asarray(ids.astype(np.int32)))
    assert np.array_equal(np.asarray(out.ids), np.asarray(q.ids))
    assert int(best_rank) == L     # nothing inserted => rank L (beyond all)


@given(st.integers(2, 8), st.integers(16, 64), st.integers(0, 2 ** 30))
def test_pq_reconstruction_bound(m, n, seed):
    """PQ quantization error must be bounded by per-subspace k-means
    radius; ADC distance of a vector to itself <= 4 * reconstruction."""
    from repro.core.quantize import (PQState, pq_encode, pq_query_tables,
                                     pq_train)
    from repro.core.types import QuantConfig
    r = np.random.default_rng(seed)
    d = m * 4
    x = jnp.asarray(r.normal(size=(max(n, 300), d)).astype(np.float32))
    st_ = pq_train(x, QuantConfig(kind="pq", pq_m=m, kmeans_iters=4))
    codes = pq_encode(st_.codebooks, x)
    lut = pq_query_tables(st_.codebooks, x[:4], "l2")
    from repro.kernels.ref import pq_adc_ref
    self_ids = jnp.arange(4, dtype=jnp.int32)[:, None]
    d_self = np.asarray(pq_adc_ref(
        lut.reshape(4, m, 256), codes, self_ids))[:, 0]
    # ADC(x, x) == ||x - x_hat||^2 — reconstruction error, must be finite
    # and far below the typical inter-point distance (~2d for N(0,1)).
    assert np.all(np.isfinite(d_self))
    assert np.all(d_self < 2 * d)


@given(st.integers(30, 200), st.integers(0, 2 ** 30))
def test_reorder_is_permutation(n, seed):
    from repro.core.reorder import apply_order, mst_reorder
    r = np.random.default_rng(seed)
    M = 4
    graph = r.integers(-1, n, size=(n, M)).astype(np.int32)
    w = r.random((n, M)).astype(np.float32)
    order = mst_reorder(graph, w, entry=0)
    assert sorted(order.tolist()) == list(range(n))
    db = r.normal(size=(n, 8)).astype(np.float32)
    db2, g2, new_of_old = apply_order(order, db, graph)
    # vector rows follow their ids
    np.testing.assert_array_equal(db2, db[order])
    # edges are preserved under relabeling
    for u_new in range(min(10, n)):
        u_old = order[u_new]
        olds = set(v for v in graph[u_old] if v >= 0)
        news = set(int(new_of_old[v]) for v in olds)
        assert set(v for v in g2[u_new] if v >= 0) == news


@given(st.integers(0, 2 ** 30))
def test_sq_roundtrip_error(seed):
    from repro.core.quantize import SQState, sq_encode, sq_train
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(200, 16)).astype(np.float32) * 5)
    stq = sq_train(x)
    codes = sq_encode(stq, x)
    dec = np.asarray(codes).astype(np.float32) * np.asarray(stq.scale) \
        + np.asarray(stq.zero)
    err = np.abs(dec - np.asarray(x))
    # max error is half a quantization bin per dim
    assert np.all(err <= np.asarray(stq.scale) * 0.5 + 1e-5)


@given(st.integers(8, 40), st.integers(2, 6), st.integers(0, 2 ** 30))
def test_refine_degree_bound(n, M, seed):
    from repro.core.build import brute_force_knn
    from repro.core.refine import refine_graph
    r = np.random.default_rng(seed)
    db = jnp.asarray(r.normal(size=(n, 8)).astype(np.float32))
    k = min(n - 1, 2 * M)
    ids, dd = brute_force_knn(db, k, "l2", chunk=16)
    g = refine_graph(db, ids, dd, M=M, rule="alpha", metric="l2", alpha=1.2,
                     ssg_angle_deg=60, iters=1, cand_cap=3 * M, entry=0,
                     search_L=8, search_passes=1, node_chunk=16)
    assert g.shape == (n, M)
    # no self edges, ids in range
    assert np.all(g < n)
    for u in range(n):
        assert u not in set(g[u][g[u] >= 0].tolist())
