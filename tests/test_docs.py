"""Docs-integrity checks, now delegated to the kbest-lint `docs_xref`
check (repro.analysis.docs, DESIGN.md §15): every `DESIGN.md §N`
citation in the tree must resolve to a real `## §N` header and the
numbered sections must be contiguous — inserting a section (e.g. §12
"Sharded search", which shifted quantization to §13) forces every stale
citation to fail here instead of silently pointing at the wrong note.

The test is a thin wrapper so the invariant keeps running under plain
pytest; the lint CLI enforces the same thing in the CI lint job (and
tests/analysis_fixtures/docs_xref/ pins that the check actually fires).
"""
from pathlib import Path

from repro.analysis import run_check
from repro.analysis.common import Tree
from repro.analysis.docs import sections_of

ROOT = Path(__file__).resolve().parents[1]


def test_docs_xref_clean():
    violations = run_check("docs_xref", ROOT)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_design_has_cost_model_section():
    # §16 is the contract cited by analysis/cost.py + core/tune.py
    secs = sections_of(Tree(ROOT))
    assert secs and 16 in secs
