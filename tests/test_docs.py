"""Docs-integrity checks: every `DESIGN.md §N` citation in the tree must
resolve to a real `## §N` section header, and the numbered sections must be
contiguous — inserting a section (e.g. §12 "Sharded search", which shifted
quantization to §13) forces every stale citation to fail here instead of
silently pointing at the wrong architecture note."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

CITATION = re.compile(r"DESIGN\.md §(\d+)")
HEADER = re.compile(r"^## §(\d+)", re.M)
# code + docs trees that cite DESIGN.md sections
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
SCAN_FILES = ("README.md", "ROADMAP.md", "CHANGES.md")


def _sections() -> set:
    return {int(n) for n in HEADER.findall((ROOT / "DESIGN.md").read_text())}


def test_design_sections_contiguous():
    secs = _sections()
    assert secs, "DESIGN.md has no numbered sections?"
    assert secs == set(range(1, max(secs) + 1)), \
        f"numbered sections must be contiguous from §1: {sorted(secs)}"


def test_design_citations_resolve():
    secs = _sections()
    files = [p for d in SCAN_DIRS for p in (ROOT / d).rglob("*.py")]
    files += [ROOT / f for f in SCAN_FILES if (ROOT / f).exists()]
    bad = []
    for p in files:
        for n in CITATION.findall(p.read_text()):
            if int(n) not in secs:
                bad.append((str(p.relative_to(ROOT)), f"§{n}"))
    assert not bad, f"unresolvable DESIGN.md citations: {bad}"
