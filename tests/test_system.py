"""End-to-end behaviour of the KBest system (paper Algorithm 1 + §3)."""
import dataclasses

import numpy as np
import pytest

from repro.core.types import SearchConfig
from repro.data.vectors import recall_at_k


def test_recall_deep(deep_index, deep_ds):
    s = SearchConfig(L=64, k=10, early_term=False)
    d, i = deep_index.search(deep_ds.queries, k=10, search_cfg=s)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.95


def test_recall_bigann(bigann_index, bigann_ds):
    s = SearchConfig(L=128, k=10, early_term=False)
    d, i = bigann_index.search(bigann_ds.queries, k=10, search_cfg=s)
    assert recall_at_k(np.asarray(i), bigann_ds.gt_ids, 10) >= 0.9


def test_larger_L_no_worse(deep_index, deep_ds):
    rs = []
    for L in (16, 48, 96):
        s = SearchConfig(L=L, k=10, early_term=False)
        _, i = deep_index.search(deep_ds.queries, k=10, search_cfg=s)
        rs.append(recall_at_k(np.asarray(i), deep_ds.gt_ids, 10))
    assert rs[0] <= rs[1] + 0.02 and rs[1] <= rs[2] + 0.02, rs


def test_results_sorted_and_valid(deep_index, deep_ds):
    s = SearchConfig(L=48, k=10, early_term=False)
    d, i = deep_index.search(deep_ds.queries, k=10, search_cfg=s)
    d, i = np.asarray(d), np.asarray(i)
    assert np.all(np.diff(d, axis=1) >= -1e-6), "distances not sorted"
    assert np.all(i >= 0) and np.all(i < deep_ds.base.shape[0])
    # returned dists match true distances of returned ids
    for q in range(5):
        vecs = deep_ds.base[i[q]]
        true = -(vecs @ deep_ds.queries[q])
        np.testing.assert_allclose(d[q], true, rtol=1e-4, atol=1e-4)


def test_early_termination_saves_hops(deep_index, deep_ds):
    base = SearchConfig(L=64, k=10, early_term=False)
    et = dataclasses.replace(base, early_term=True, et_patience=12)
    _, i0, st0 = deep_index.search(deep_ds.queries, search_cfg=base,
                                   with_stats=True)
    _, i1, st1 = deep_index.search(deep_ds.queries, search_cfg=et,
                                   with_stats=True)
    r0 = recall_at_k(np.asarray(i0), deep_ds.gt_ids, 10)
    r1 = recall_at_k(np.asarray(i1), deep_ds.gt_ids, 10)
    assert np.asarray(st1.n_hops).mean() <= np.asarray(st0.n_hops).mean()
    assert r1 >= r0 - 0.08, (r0, r1)   # bounded recall cost


def test_early_term_infinite_patience_never_fires(deep_index, deep_ds):
    s = SearchConfig(L=32, k=10, early_term=True, et_patience=10_000)
    _, _, st = deep_index.search(deep_ds.queries, search_cfg=s,
                                 with_stats=True)
    assert not np.asarray(st.early_terminated).any()


def test_bitmap_mode_fewer_dists_same_recall(deep_index, deep_ds):
    sq = SearchConfig(L=48, k=10, early_term=False, visited_mode="queue")
    sb = dataclasses.replace(sq, visited_mode="bitmap")
    _, iq, stq = deep_index.search(deep_ds.queries, search_cfg=sq,
                                   with_stats=True)
    _, ib, stb = deep_index.search(deep_ds.queries, search_cfg=sb,
                                   with_stats=True)
    rq = recall_at_k(np.asarray(iq), deep_ds.gt_ids, 10)
    rb = recall_at_k(np.asarray(ib), deep_ds.gt_ids, 10)
    assert np.asarray(stb.n_dist).mean() <= np.asarray(stq.n_dist).mean()
    assert abs(rq - rb) < 0.08, (rq, rb)


def test_kernel_dist_path_matches_ref(deep_index, deep_ds):
    sref = SearchConfig(L=48, k=10, early_term=False, dist_impl="ref")
    sker = dataclasses.replace(sref, dist_impl="kernel")
    _, i0 = deep_index.search(deep_ds.queries, search_cfg=sref)
    _, i1 = deep_index.search(deep_ds.queries, search_cfg=sker)
    # identical traversal => identical results
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


# the basic save/load round-trip lives in tests/test_saveload.py,
# parameterized over the whole quant registry; the tests below keep the
# sidecar-naming contracts it doesn't cover


def test_save_same_stem_no_clobber(tmp_path, deep_ds, deep_index):
    """save("a.graph") and save("a.ivf") used to both write their metadata
    to "a.json" (with_suffix), so whichever saved last silently owned both
    indexes' config. Sidecars must be per-full-name."""
    from repro.core.index import KBest
    from repro.core.types import (IVFConfig, IndexConfig, QuantConfig,
                                  SearchConfig)
    ivf = KBest(IndexConfig(
        dim=deep_ds.base.shape[1], metric=deep_ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4, list_pad=32),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4),
        search=SearchConfig(L=64, k=10, nprobe=8))).add(deep_ds.base)
    deep_index.save(str(tmp_path / "a.graph"))
    ivf.save(str(tmp_path / "a.ivf"))
    assert (tmp_path / "a.graph.json").exists()
    assert (tmp_path / "a.ivf.json").exists()
    assert not (tmp_path / "a.json").exists()
    g2 = KBest.load(str(tmp_path / "a.graph"))
    v2 = KBest.load(str(tmp_path / "a.ivf"))
    assert g2.config.index_type == "graph" and v2.config.index_type == "ivf"
    _, i0 = deep_index.search(deep_ds.queries[:5])
    _, i1 = g2.search(deep_ds.queries[:5])
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_load_old_sidecar_name(tmp_path, deep_index, deep_ds):
    """Pre-fix saves put metadata at with_suffix(".json"); load must still
    find it when the new full-name sidecar is absent."""
    from repro.core.index import KBest
    p = tmp_path / "old.npz"
    deep_index.save(str(p))
    (p.with_name("old.npz.json")).rename(tmp_path / "old.json")
    idx2 = KBest.load(str(p))
    _, i0 = deep_index.search(deep_ds.queries[:5])
    _, i1 = idx2.search(deep_ds.queries[:5])
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_config_from_dict_ignores_unknown_keys():
    """Metadata written by newer versions (extra config fields) must load
    on older checkouts instead of raising TypeError — but the drop is
    warned about per config class (tests/test_saveload.py pins the
    warning text), never silent."""
    import pytest

    from repro.core.index import _config_from_dict
    d = {
        "dim": 16, "metric": "l2", "index_type": "graph",
        "build": {"M": 8, "knn_k": 16, "from_the_future": 1},
        "search": {"L": 32, "k": 5, "hyperdrive": True},
        "quant": {"kind": "pq4", "pq_m": 8, "warp_factor": 9},
        "ivf": {"nlist": 4, "flux_capacitor": "on"},
    }
    with pytest.warns(UserWarning) as rec:
        cfg = _config_from_dict(d)
    assert len(rec) == 4        # one warning per config class with drops
    assert cfg.build.M == 8 and cfg.search.L == 32
    assert cfg.quant.kind == "pq4" and cfg.ivf.nlist == 4


def test_et_tuner_improves_hops(deep_index, deep_ds):
    from repro.core.tune import tune_early_term
    base = SearchConfig(L=64, k=10, early_term=False)
    tuned = tune_early_term(deep_index, deep_ds.queries[:20],
                            deep_ds.gt_ids[:20], base, recall_target=0.95,
                            patience_hi=32)
    _, i, st = deep_index.search(deep_ds.queries, search_cfg=tuned,
                                 with_stats=True)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.85
