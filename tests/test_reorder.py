"""Graph reordering (Algorithm 2): permutation validity, locality gain,
search-result invariance."""
import dataclasses

import numpy as np

from repro.core import reorder
from repro.core.types import SearchConfig
from repro.data.vectors import recall_at_k


def _clustered_graph(n=400, M=6, seed=0):
    """Ring of dense clusters — a layout where reordering matters."""
    r = np.random.default_rng(seed)
    g = np.full((n, M), -1, dtype=np.int32)
    c = 8
    per = n // c
    # scatter node ids so the natural order is maximally non-local
    perm = r.permutation(n)
    for ci in range(c):
        members = perm[ci * per:(ci + 1) * per]
        for u in members:
            nbrs = r.choice(members, size=M - 1, replace=False)
            g[u, :M - 1] = nbrs
        # one shortcut to the next cluster
        g[members[0], M - 1] = perm[((ci + 1) % c) * per]
    w = r.random((n, M)).astype(np.float32)
    return g, w


def test_mst_reorder_improves_locality():
    g, w = _clustered_graph()
    before = reorder.bandwidth_stats(g)
    order = reorder.mst_reorder(g, w, entry=0)
    _, g2, _ = reorder.apply_order(order, np.zeros((g.shape[0], 4)), g)
    after = reorder.bandwidth_stats(g2)
    assert after["mean_gap"] < before["mean_gap"], (before, after)


def test_mst_reorder_improves_real_index_locality(deep_index):
    """On an actual proximity-graph index (the use case, not a synthetic
    ring), Algorithm 2 must beat the build ordering on edge locality.
    Cuthill-McKee wins raw bandwidth by construction (it IS the bandwidth
    heuristic); the paper's argument for MST-order is that CM's BFS
    relabeling destroys long-range ANNS shortcuts — asserted on QPS in
    benchmarks/ablation.py, not here."""
    g = np.asarray(deep_index.graph)
    n = g.shape[0]
    rng = np.random.default_rng(0)
    scramble = rng.permutation(n)
    _, g_scr, new_of_old = reorder.apply_order(scramble, np.zeros((n, 4)), g)
    before = reorder.bandwidth_stats(g_scr)["mean_gap"]
    w = rng.random(g_scr.shape).astype(np.float32)
    order = reorder.mst_reorder(g_scr, w, entry=int(new_of_old[deep_index.entry]))
    _, g_mst, _ = reorder.apply_order(order, np.zeros((n, 4)), g_scr)
    after = reorder.bandwidth_stats(g_mst)["mean_gap"]
    assert after < before, (before, after)


def test_reorder_preserves_search_results(deep_ds):
    """Search results (user-id space) must be invariant to reorder mode."""
    from repro.core.index import KBest
    from repro.core.types import BuildConfig, IndexConfig
    base = dict(M=24, knn_k=32, builder="brute", refine_iters=0,
                refine_cands=64, search_passes=1)
    s = SearchConfig(L=64, k=10, early_term=False)
    recalls = {}
    for mode in ("none", "mst", "cm"):
        cfg = IndexConfig(dim=deep_ds.base.shape[1], metric=deep_ds.metric,
                          build=BuildConfig(reorder=mode, **base), search=s)
        idx = KBest(cfg).add(deep_ds.base)
        _, i = idx.search(deep_ds.queries, k=10, search_cfg=s)
        recalls[mode] = recall_at_k(np.asarray(i), deep_ds.gt_ids, 10)
    # graph construction is order-dependent only through tie-breaks;
    # recall must be statistically identical
    assert max(recalls.values()) - min(recalls.values()) < 0.1, recalls


def test_disconnected_graph_still_permutes():
    g = np.full((10, 2), -1, dtype=np.int32)
    g[0, 0] = 1
    g[1, 0] = 0
    g[5, 0] = 6   # separate component
    w = np.ones((10, 2), dtype=np.float32)
    order = reorder.mst_reorder(g, w, entry=0)
    assert sorted(order.tolist()) == list(range(10))
