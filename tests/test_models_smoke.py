"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward/train step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as reg

LM_ARCHS = ["qwen2_5_14b", "chatglm3_6b", "gemma_2b", "kimi_k2_1t_a32b",
            "llama4_scout_17b_a16e"]
RECSYS_ARCHS = ["deepfm", "fm", "bst", "bert4rec"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as T
    cfg = reg.get(arch).smoke_config()
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    # train step: loss + grads finite
    (loss, metrics), grads = jax.value_and_grad(
        T.loss_fn, has_aux=True)(p, {"tokens": toks}, cfg)
    assert jnp.isfinite(loss), arch
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and gn > 0
    # forward logits shape
    logits, _ = T.forward(p, toks[:, :-1], cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # decode step
    cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
    lg, cache = T.decode_step(p, cache, toks[:, :1], cfg)
    assert lg.shape == (2, 1, cfg.vocab)
    assert int(cache["len"][0]) == 1
    # prefill
    lg2, cache2 = T.prefill(p, toks[:, :8], cfg)
    assert cache2["k"].shape[2] == 8 and lg2.shape[0] == 2


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.models import recsys as R
    cfg = reg.get(arch).smoke_config()
    p = R.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B = 4
    if cfg.kind in ("fm", "deepfm"):
        batch = {"sparse_ids": jax.random.randint(
            key, (B, cfg.n_sparse), 0, cfg.vocab_per_field),
            "label": jnp.ones((B,), jnp.float32)}
    elif cfg.kind == "bst":
        batch = {"hist": jax.random.randint(key, (B, cfg.seq_len), 0, cfg.n_items),
                 "target": jax.random.randint(key, (B,), 0, cfg.n_items),
                 "label": jnp.ones((B,), jnp.float32)}
    else:
        batch = {"seq": jax.random.randint(key, (B, cfg.seq_len), 0, cfg.n_items),
                 "labels": jax.random.randint(key, (B, cfg.seq_len), -1, cfg.n_items)}
    (loss, _), grads = jax.value_and_grad(
        R.loss_fn, has_aux=True)(p, batch, cfg)
    assert jnp.isfinite(loss)
    # serve + retrieval paths
    sb = dict(batch)
    if cfg.kind == "bert4rec":
        sb["cand"] = jax.random.randint(key, (B,), 0, cfg.n_items)
    out = R.serve_step(p, sb, cfg)
    assert out.shape[0] == B and bool(jnp.all(jnp.isfinite(out)))
    d, i = R.serve_retrieval(p, batch, cfg, k=5)
    assert i.shape == (B, 5) and bool(jnp.all(i >= 0))


def test_gnn_smoke():
    from repro.data.pipeline import gnn_minibatches
    from repro.models import dimenet as D
    cfg = reg.get("dimenet").smoke_config()
    p = D.init_params(cfg, jax.random.PRNGKey(0))
    it = gnn_minibatches(n_nodes=500, d_feat=cfg.d_feat, batch_nodes=8,
                         fanouts=(3, 2), n_classes=cfg.n_out, triplet_cap=4)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    (loss, _), grads = jax.value_and_grad(
        D.loss_fn, has_aux=True)(p, batch, cfg)
    assert jnp.isfinite(loss)
    out = D.forward(p, batch, cfg)
    assert out.shape == (batch["feats"].shape[0], cfg.n_out)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_gnn_molecule_smoke():
    from repro.data.pipeline import molecule_batches
    from repro.models import dimenet as D
    import dataclasses
    cfg = dataclasses.replace(reg.get("dimenet").smoke_config(),
                              task="graph_reg", n_out=1)
    p = D.init_params(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             next(molecule_batches(n_atoms=6, n_edges=12, batch=4,
                                   d_feat=cfg.d_feat)).items()}
    loss, _ = D.loss_fn(p, batch, cfg, n_graphs=4)
    assert jnp.isfinite(loss)


def test_all_archs_have_full_configs():
    for arch in reg.ARCHS:
        mod = reg.get(arch)
        assert mod.FAMILY in ("lm", "gnn", "recsys")
        assert len(mod.SHAPES) == 4
        if mod.FAMILY == "gnn":
            cfg = mod.full_config("full_graph_sm")
        else:
            cfg = mod.full_config()
        assert cfg is not None


def test_assigned_hyperparameters_exact():
    """The full configs must match the assignment table exactly."""
    q = reg.get("qwen2_5_14b").full_config()
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (48, 5120, 40, 8, 13824, 152064, True)
    c = reg.get("chatglm3_6b").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.rotary_frac) == (28, 4096, 32, 2, 13696, 65024, 0.5)
    g = reg.get("gemma_2b").full_config()
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab, g.head_dim) == (18, 2048, 8, 1, 16384, 256000, 256)
    k = reg.get("kimi_k2_1t_a32b").full_config()
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads, k.vocab,
            k.moe.n_experts, k.moe.top_k) == (61, 7168, 64, 8, 163840, 384, 8)
    l = reg.get("llama4_scout_17b_a16e").full_config()
    assert (l.n_layers, l.d_model, l.n_heads, l.n_kv_heads, l.d_ff, l.vocab,
            l.moe.n_experts, l.moe.top_k) == (48, 5120, 40, 8, 8192, 202048, 16, 1)
    d = reg.get("dimenet").full_config("full_graph_sm")
    assert (d.n_blocks, d.d_hidden, d.n_bilinear, d.n_spherical,
            d.n_radial) == (6, 128, 8, 7, 6)
    df = reg.get("deepfm").full_config()
    assert (df.n_sparse, df.embed_dim, df.mlp_dims) == (39, 10, (400, 400, 400))
    b4 = reg.get("bert4rec").full_config()
    assert (b4.d_model, b4.n_blocks, b4.n_heads, b4.seq_len) == (64, 2, 2, 200)
    bs = reg.get("bst").full_config()
    assert (bs.d_model, bs.seq_len, bs.n_blocks, bs.n_heads,
            bs.mlp_dims) == (32, 20, 1, 8, (1024, 512, 256))
    f = reg.get("fm").full_config()
    assert (f.n_sparse, f.embed_dim) == (39, 10)
