"""kbest-lint (DESIGN.md §15) pins both directions: the live tree passes
every check, and each check demonstrably FIRES on its seeded-violation
fixture (tests/analysis_fixtures/) — a lint that cannot fail is no lint.
Plus unit coverage for the subtle bits: property-bridge liveness,
is-None/shape-attr tracing exemptions, VMEM table coverage."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import CHECKS, default_root, run_all, run_check
from repro.analysis import cost, docs, parity, registry, tracing, vmem
from repro.analysis.common import Tree

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "analysis_fixtures"

FIXTURE_FOR = {
    "kernel_parity": "parity",
    "registry": "registry",
    "dead_knobs": "dead_knobs",
    "tracing_safety": "tracing",
    "vmem_budget": "vmem",
    "docs_xref": "docs_xref",
    "cost": "cost",
}


def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})


# ------------------------------------------------------------ clean tree
def test_clean_tree_passes():
    violations = run_all(ROOT)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_default_root_is_this_checkout():
    assert default_root() == ROOT


def test_cli_exit_zero_on_clean_tree():
    r = _cli()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 violation(s)" in r.stdout


# --------------------------------------------------------- checks fire
@pytest.mark.parametrize("check", sorted(CHECKS))
def test_fixture_fires(check):
    violations = run_check(check, FIXTURES / FIXTURE_FOR[check])
    own = [v for v in violations if v.check == check]
    assert own, f"{check} did not fire on its seeded fixture"


@pytest.mark.parametrize("check", sorted(CHECKS))
def test_cli_exit_nonzero_on_fixture(check):
    r = _cli("--root", str(FIXTURES / FIXTURE_FOR[check]), "--check", check)
    assert r.returncode == 1, r.stdout + r.stderr


def test_fixture_messages_name_the_seeded_violation():
    knob = run_check("dead_knobs", FIXTURES / "dead_knobs")
    assert any("phantom_knob" in v.message for v in knob)
    # the max_hops property bridge (hops_bound) keeps it live
    assert not any("max_hops" in v.message for v in knob)

    reg = run_check("registry", FIXTURES / "registry")
    assert any("zq" in v.message for v in reg)
    assert any("hand-enumerated" in v.message for v in reg)

    tr = run_check("tracing_safety", FIXTURES / "tracing")
    kinds = {m for v in tr for m in ("`if`", "`assert`", "`float()`")
             if m in v.message}
    assert kinds == {"`if`", "`assert`", "`float()`"}, tr

    co = run_check("cost", FIXTURES / "cost")
    assert any("mystery_scan" in v.message for v in co)
    assert any("zz" in v.message for v in co)  # the unresolvable grid dim

    dx = run_check("docs_xref", FIXTURES / "docs_xref")
    assert any("§3" in v.message for v in dx)       # numbering gap
    assert any("§9" in v.message for v in dx)       # dangling citation


def test_dead_knobs_covers_serving_classes():
    """The serve-tier extension (DESIGN.md §17) fires allowlist-free on
    unread Request/DegradePolicy fields, under the relaxed rule that
    self-reads in the defining class keep a policy knob live."""
    v = run_check("dead_knobs", FIXTURES / "dead_knobs_serve")
    msgs = [x.message for x in v]
    assert any("Request.phantom_deadline_knob" in m for m in msgs), msgs
    assert any("DegradePolicy.phantom_watermark_ms" in m for m in msgs), msgs
    # live fields — externally read (deadline_ms, queries) or self-read by
    # the class's own methods (ladder, high_ms) — must NOT fire
    for live in ("deadline_ms", "queries", "ladder", "high_ms"):
        assert not any(f".{live}" in m for m in msgs), (live, msgs)


def test_dead_knobs_serve_fields_live_on_real_tree():
    """Every field the scheduler/degrade classes declare is actually
    consulted in src/ — the check that guards this PR's own knobs."""
    assert run_check("dead_knobs", ROOT) == []


# ----------------------------------------------------------- unit bits
def test_parity_discovers_all_kernels():
    kernels = {name for _, name, _ in parity.find_kernels(Tree(ROOT))}
    # the ops.py dispatch surface IS the kernel surface
    import repro.kernels.ops as ops
    public_ops = {n for n in dir(ops)
                  if not n.startswith("_") and callable(getattr(ops, n))
                  and getattr(ops, n).__module__ == "repro.kernels.ops"}
    assert kernels == public_ops
    assert len(kernels) >= 14


def test_registry_kinds_match_runtime():
    from repro.analysis.common import assigned_tuple_of_strings
    from repro.core.types import QUANT_KINDS
    mod = Tree(ROOT).parse("src/repro/core/types.py")
    assert assigned_tuple_of_strings(mod, "QUANT_KINDS") == QUANT_KINDS
    assert set(QUANT_KINDS) == set(registry.KIND_SIDECARS)


def test_vmem_report_covers_every_kernel():
    tree = Tree(ROOT)
    estimates = vmem.estimate(tree)
    assert {e.name for e in estimates} == \
        {name for _, name, _ in parity.find_kernels(tree)}
    for e in estimates:
        assert e.notes == [], f"{e.name}: unresolved dims {e.notes}"
        assert e.n_blocks > 0
        assert 0 < e.total_bytes <= vmem.DEFAULT_BUDGET
    table = vmem.report(tree)
    assert "batch_dist" in table and "scratch" in table


def test_tracing_exemptions_hold_on_live_tree():
    """search()'s `is None` branches and the wrappers' shape asserts must
    not be flagged — the exemptions are what makes the check adoptable."""
    assert run_check("tracing_safety", ROOT) == []


def test_cost_model_covers_every_kernel():
    """KERNEL_COSTS and the AST estimate must cover exactly the
    find_kernels surface, with every grid dim resolved (no notes)."""
    tree = Tree(ROOT)
    kernels = {name for _, name, _ in parity.find_kernels(tree)}
    assert set(cost.KERNEL_COSTS) == kernels
    ests = cost.estimate(tree)
    assert {e.name for e in ests} == kernels
    for e in ests:
        assert e.notes == [], f"{e.name}: {e.notes}"
        assert e.flops > 0 and e.hbm_bytes > 0, e.name


def test_cost_model_orders_kernel_families():
    """The closed forms must reproduce the orderings the kernels were
    built for: pq4 ADC does 16x fewer MACs than pq8 (K=16 vs 256), and
    sq moves ~4x fewer gather bytes than full-precision."""
    w = cost.Workload()
    pq8_f, _, _ = cost.kernel_cost("pq_adc", w)
    pq4_f, _, _ = cost.kernel_cost("pq4_adc", w)
    assert pq4_f < pq8_f
    _, full_b, _ = cost.kernel_cost("gather_dist", w)
    _, sq_b, _ = cost.kernel_cost("sq_gather_dist", w)
    assert sq_b < full_b
    # per-query composition: IVF cost strictly increases with nprobe
    import dataclasses as dc
    costs = [cost.ivf_search_cost(dc.replace(w, index_type="ivf",
                                             nprobe=p)).seconds
             for p in (4, 16, 64)]
    assert costs == sorted(costs) and costs[0] < costs[-1]


def test_ivf_n_dist_exact_arithmetic():
    """n_dist = scanned + min(rerank_depth, cand_width, scanned) — the
    closed form benchmarks/roofline.py asserts against live runs."""
    w = cost.Workload(index_type="ivf", n=5000, k=10, L=128, nprobe=24,
                      rerank=0)
    nl, fill, ml, P, Lp, width = cost.ivf_geometry(w)
    # pq with rerank=0 reranks the WHOLE merged candidate queue
    r = cost.ivf_rerank_depth(w)
    assert r == width
    big = 10_000
    assert cost.ivf_n_dist_exact(w, big) == big + min(r, width, big)
    # fewer scanned codes than the rerank depth: rerank is capped by it
    assert cost.ivf_n_dist_exact(w, 3) == 3 + min(r, width, 3) == 6


def test_cli_json_payload(tmp_path):
    out = tmp_path / "lint.json"
    r = _cli("--json", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    import json
    payload = json.loads(out.read_text())
    assert payload["ok"] is True and payload["violations"] == []
    kernels = {name for _, name, _ in parity.find_kernels(Tree(ROOT))}
    assert {row["name"] for row in payload["vmem"]} == kernels
    assert {row["name"] for row in payload["cost"]["kernels"]} == kernels
    assert payload["cost"]["queries"], "per-query cost table missing"


def test_tracing_taint_propagates_through_assignment():
    import ast
    from repro.analysis.tracing import _Taint
    fn = ast.parse("def k(x_ref, o_ref):\n"
                   "    v = x_ref[0] * 2\n"
                   "    w = v + 1\n").body[0]
    t = _Taint({"x_ref", "o_ref"})
    t.propagate(fn)
    assert {"v", "w"} <= t.names
    # static facts cut the taint
    fn2 = ast.parse("def k(x_ref):\n    n = x_ref.shape\n").body[0]
    t2 = _Taint({"x_ref"})
    t2.propagate(fn2)
    assert "n" not in t2.names
