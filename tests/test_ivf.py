"""IVF-PQ subsystem tests (DESIGN.md §4): recall vs brute-force oracle,
ivf_scan kernel vs jnp reference, list-layout invariants, save/load."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ivf as ivf_mod
from repro.core.index import KBest
from repro.core.types import IVFConfig, IndexConfig, QuantConfig, SearchConfig
from repro.data.vectors import make_dataset, recall_at_k

RNG = np.random.default_rng(11)


def _ivf_cfg(dim, metric, **kw):
    return IndexConfig(
        dim=dim, metric=metric, index_type="ivf",
        ivf=IVFConfig(nlist=kw.pop("nlist", 0),
                      kmeans_iters=kw.pop("kmeans_iters", 8),
                      list_pad=kw.pop("list_pad", 128)),
        quant=QuantConfig(kind="pq", pq_m=kw.pop("pq_m", 16),
                          kmeans_iters=kw.pop("pq_iters", 6)),
        search=SearchConfig(L=kw.pop("L", 128), k=10,
                            nprobe=kw.pop("nprobe", 16)))


# ------------------------------------------------------------------- kernel
@pytest.mark.parametrize("q,p,nlist,max_len,m,L", [
    (3, 2, 7, 24, 8, 8),
    (5, 4, 16, 40, 16, 16),
])
def test_ivf_scan_kernel_vs_ref(q, p, nlist, max_len, m, L):
    from repro.kernels import ops, ref
    luts = jnp.asarray(RNG.normal(size=(q, p, m, 256)).astype(np.float32))
    codes = jnp.asarray(
        RNG.integers(0, 256, size=(nlist, max_len, m)).astype(np.uint8))
    # ragged valid prefixes, -1 padding (like real inverted lists)
    ids = np.full((nlist, max_len), -1, np.int32)
    for c in range(nlist):
        n_valid = int(RNG.integers(0, max_len + 1))
        ids[c, :n_valid] = RNG.choice(10_000, size=n_valid, replace=False)
    ids = jnp.asarray(ids)
    probes = jnp.asarray(
        np.stack([RNG.choice(nlist, size=p, replace=False)
                  for _ in range(q)]).astype(np.int32))

    kd, ki = ops.ivf_scan(luts, codes, ids, probes, L=L)
    rd, ri = ref.ivf_scan_ref(luts, codes, ids, probes, L)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    # ascending per (q, p), padding at the tail
    kd = np.asarray(kd)
    assert np.all(kd[:, :, :-1] <= kd[:, :, 1:])
    assert np.all((np.asarray(ki) >= 0) == np.isfinite(kd))


# -------------------------------------------------------------------- build
def test_ivf_lists_partition_db():
    x = jnp.asarray(RNG.normal(size=(500, 32)).astype(np.float32))
    state = ivf_mod.build_ivf(
        x, IVFConfig(nlist=10, kmeans_iters=5, list_pad=8),
        QuantConfig(kind="pq", pq_m=8, kmeans_iters=3))
    ids = np.asarray(state.list_ids)
    valid = ids[ids >= 0]
    assert sorted(valid.tolist()) == list(range(500))
    assert state.max_len % 8 == 0


def test_ivf_exhaustive_probe_matches_pq_brute_force():
    """nprobe == nlist must equal a flat scan of all PQ codes (the IVF
    partitioning only routes, it must not change ADC distances)."""
    from repro.core.quantize import pq_query_tables
    from repro.kernels.ref import pq_adc_ref
    n, d, L = 400, 32, 32
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(size=(6, d)).astype(np.float32))
    state = ivf_mod.build_ivf(
        x, IVFConfig(nlist=8, kmeans_iters=5, list_pad=8, residual=False),
        QuantConfig(kind="pq", pq_m=8, kmeans_iters=4))
    d_ivf, i_ivf, _ = ivf_mod.search_ivf(state, q, nprobe=8, L=L, metric="l2")

    # flat ADC over all n codes, same codebooks (residual=False => raw x)
    codes = np.zeros((n, state.pq.m), np.uint8)
    ids_h = np.asarray(state.list_ids)
    codes[ids_h[ids_h >= 0]] = np.asarray(state.list_codes)[ids_h >= 0]
    lut = pq_query_tables(state.pq.codebooks, q, "l2").reshape(6, 8, 256)
    all_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (6, n))
    d_flat = np.asarray(pq_adc_ref(lut, jnp.asarray(codes), all_ids))
    top = np.sort(d_flat, axis=1)[:, :L]
    np.testing.assert_allclose(np.asarray(d_ivf), top, rtol=1e-4, atol=1e-4)
    # sets agree (ties can permute ids)
    for a, b in zip(np.asarray(i_ivf), np.argsort(d_flat, axis=1)[:, :L]):
        assert len(set(a.tolist()) & set(b.tolist())) >= L - 2


# ------------------------------------------------------------------- recall
def test_ivf_recall_50k_bigann():
    """Acceptance: recall@10 >= 0.90 on a 50k synthetic set, re-rank on."""
    ds = make_dataset("bigann_like", n=50_000, n_queries=50, k=10)
    cfg = _ivf_cfg(128, "l2", pq_m=16, kmeans_iters=10, pq_iters=8,
                   L=192, nprobe=32)
    idx = KBest(cfg).add(ds.base)
    _, ids = idx.search(ds.queries, k=10)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert rec >= 0.90, rec


def test_ivf_recall_gaussian_mixture_ip():
    ds = make_dataset("glove_like", n=8000, n_queries=40, k=10)
    cfg = _ivf_cfg(100, "ip", pq_m=20, L=128, nprobe=24, list_pad=8)
    idx = KBest(cfg).add(ds.base)
    _, ids = idx.search(ds.queries, k=10)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert rec >= 0.85, rec


def test_ivf_kernel_impl_matches_ref_impl(bigann_ds):
    cfg = _ivf_cfg(128, "l2", nlist=32, L=64, nprobe=8, list_pad=8)
    idx = KBest(cfg).add(bigann_ds.base)
    s_k = dataclasses.replace(cfg.search, dist_impl="kernel")
    d_r, i_r = idx.search(bigann_ds.queries[:8], k=10)
    d_k, i_k = idx.search(bigann_ds.queries[:8], k=10, search_cfg=s_k)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


# save/load round-trips live in tests/test_saveload.py, parameterized
# over the whole quant registry (graph + IVF x every IVF-capable kind).
