"""Degrade ladder + policy (DESIGN.md §17): every rung is a valid
standalone SearchConfig whose measured recall behaves (via the tuner's
_memo_eval on a 5k split), the ladder is strictly monotone in predicted
cost, and DegradePolicy walks it down under sustained queue delay and
back up on recovery — with hysteresis, never past the ends."""
import dataclasses

import numpy as np
import pytest

from repro.analysis.cost import predict_service_s
from repro.configs import kbest as kcfg
from repro.core.index import KBest
from repro.core.tune import _memo_eval
from repro.core.types import SearchConfig
from repro.serve import (DegradePolicy, FaultInjector, Request, SearchEngine,
                         serve_loop)

LADDER_CASES = {
    "graph": kcfg.index_config("deep_like"),
    "ivf": kcfg.ivf_index_config("deep_like"),
    "bin": kcfg.bin_index_config("deep_like"),
    "ivf_bin": kcfg.ivf_bin_index_config("deep_like"),
}


# ----------------------------------------------------------- ladder shape
@pytest.mark.parametrize("name", sorted(LADDER_CASES))
def test_ladder_monotone_predicted_cost(name):
    cfg = LADDER_CASES[name]
    ladder = kcfg.degrade_ladder(cfg)
    assert len(ladder) >= 2, "a one-rung ladder cannot degrade"
    assert ladder[0] == cfg.search, "rung 0 must be the preset itself"
    costs = [predict_service_s(cfg, s) for s in ladder]
    assert all(a > b for a, b in zip(costs, costs[1:])), costs


@pytest.mark.parametrize("name", sorted(LADDER_CASES))
def test_ladder_rungs_are_valid_standalone_configs(name):
    for s in kcfg.degrade_ladder(LADDER_CASES[name]):
        assert isinstance(s, SearchConfig)
        assert s.k <= s.L and s.beam_width <= s.L
        assert s.nprobe >= 1 and s.rescore_factor >= 1
        # the frozen-dataclass invariants re-check on reconstruction
        SearchConfig(**dataclasses.asdict(s))


def test_ladder_rungs_searchable_via_memo_eval():
    """Every rung of the IVF deep_like ladder actually runs on a 5k split,
    through the same memoized evaluator the tuner uses; quality must not
    INCREASE down the ladder beyond noise (cheaper rungs trade recall)."""
    from repro.data.vectors import make_dataset
    ds = make_dataset("deep_like", n=5000, n_queries=50, k=10)
    cfg = kcfg.ivf_index_config("deep_like")
    index = KBest(dataclasses.replace(cfg, dim=ds.base.shape[1])).add(ds.base)
    ev = _memo_eval(index, ds.queries, ds.gt_ids)
    ladder = kcfg.degrade_ladder(index.config)
    recalls = []
    for rung in ladder:
        rec, _ = ev(rung)
        assert 0.0 <= rec <= 1.0
        recalls.append(rec)
    assert recalls[0] >= recalls[-1], recalls
    assert recalls[0] >= 0.8, f"full-quality rung too weak: {recalls}"
    # the memoized evaluator must dedupe repeat rung evaluations
    n_cached = len(ev.cache)
    ev(ladder[0])
    assert len(ev.cache) == n_cached


# ---------------------------------------------------------------- policy
def _ladder3():
    base = SearchConfig(L=64, k=10)
    return (base,
            dataclasses.replace(base, L=32),
            dataclasses.replace(base, L=16))


def test_policy_steps_down_and_recovers():
    p = DegradePolicy(ladder=_ladder3(), high_ms=100.0, low_ms=10.0,
                      patience=2)
    assert p.observe(500.0) == 0          # 1 over: not yet
    assert p.observe(500.0) == 1          # patience reached: step down
    assert p.observe(500.0) == 1
    assert p.observe(500.0) == 2          # and again
    assert p.observe(500.0) == 2          # bottom rung: capped
    assert p.observe(1.0) == 2
    assert p.observe(1.0) == 1            # recovery steps back up
    assert p.observe(1.0) == 1
    assert p.observe(1.0) == 0
    assert p.observe(1.0) == 0            # top rung: capped
    assert p.transitions == [(2, 0, 1), (4, 1, 2), (7, 2, 1), (9, 1, 0)]
    assert sum(p.occupancy.values()) == 10


def test_policy_hysteresis_band_holds_level():
    p = DegradePolicy(ladder=_ladder3(), high_ms=100.0, low_ms=10.0,
                      patience=1)
    p.observe(500.0)
    assert p.level == 1
    for _ in range(20):                   # inside the band: no movement
        assert p.observe(50.0) == 1
    assert len(p.transitions) == 1


def test_policy_patience_requires_consecutive_observations():
    p = DegradePolicy(ladder=_ladder3(), high_ms=100.0, low_ms=10.0,
                      patience=3)
    for _ in range(5):                    # over, over, reset, over, over...
        p.observe(500.0)
        p.observe(500.0)
        p.observe(1.0)
    assert p.level == 0 and p.transitions == []


def test_policy_apply_preserves_request_k():
    p = DegradePolicy(ladder=_ladder3(), high_ms=1.0, low_ms=0.5, patience=1)
    ask = SearchConfig(L=128, k=20)
    assert p.apply(ask) == ask            # rung 0: untouched
    p.observe(100.0)
    got = p.apply(ask)
    assert got.k == 20 and got.L == 32    # rung knobs, request's k


# ------------------------------------------------------ serve integration
@pytest.fixture(scope="module")
def tiny_engine():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((240, 32)).astype(np.float32)
    index = KBest(kcfg.smoke_config()).add(x)
    return SearchEngine(index, min_bucket=8, max_bucket=32), x


def test_serve_loop_degrades_under_overload_and_recovers(tiny_engine):
    eng, x = tiny_engine
    ladder = kcfg.degrade_ladder(eng.index.config)
    policy = DegradePolicy(ladder=ladder, high_ms=100.0, low_ms=10.0,
                           patience=2)
    q = x[:4]
    # burst at t=0 behind a 1s virtual spike -> sustained queue delay;
    # then arrivals spaced 10s apart -> recovery
    reqs = [Request(queries=q, request_id=i, arrival_ms=0.0)
            for i in range(6)]
    reqs += [Request(queries=q, request_id=10 + i,
                     arrival_ms=20_000.0 + 10_000.0 * i) for i in range(6)]
    rep = serve_loop(eng, reqs, coalesce=False, degrade=policy,
                     faults=FaultInjector(latency_spikes={0: 1000.0}))
    levels = {r.request_id: r.degrade_level for r in rep.results}
    assert levels[0] == 0                 # first request: no delay yet
    assert max(levels.values()) >= 1, levels
    assert levels[15] == 0, levels        # spaced arrivals recovered
    assert policy.transitions, "no transitions recorded"
    st = eng.stats()
    assert sum(n for _, n in st.degrade_occupancy) == len(reqs)
    assert any(lvl > 0 for lvl, _ in st.degrade_occupancy)
    # every result still served (degradation, not shedding)
    assert rep.n_served == sum(r.n_queries for r in reqs)
