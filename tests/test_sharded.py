"""ShardedKBest subsystem (DESIGN.md §12): single-shard bit-parity with
KBest across families and quantizers, multi-shard recall floor, stats-merge
semantics, global-id translation, uneven-shard handling, save/load of the
per-shard artifact layout, and serving-engine integration. All on the CPU
test session — ShardedKBest is device-count agnostic (the shard_map device
lowering is covered in tests/test_sharding.py)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import KBest
from repro.core.sharded import (ShardedKBest, merge_stats,
                                pad_to_shard_boundary, shard_bounds)
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k

N, Q, K = 800, 24, 10


@pytest.fixture(scope="session")
def sh_ds():
    return make_dataset("deep_like", n=N, n_queries=Q, k=K)


def _cfg(dim: int, metric: str, family: str, quant: str,
         n_shards: int = 1) -> IndexConfig:
    if family == "graph":
        q = {"full": QuantConfig(),
             "pq4": QuantConfig(kind="pq4", pq_m=8, kmeans_iters=3)}[quant]
        return IndexConfig(
            dim=dim, metric=metric, n_shards=n_shards, quant=q,
            build=BuildConfig(M=16, knn_k=24, builder="brute",
                              refine_iters=1, refine_cands=48,
                              reorder="mst"),
            search=SearchConfig(L=32, k=K, early_term=(quant == "pq4"),
                                n_entries=4))
    q = {"pq": QuantConfig(kind="pq", pq_m=8, kmeans_iters=3),
         "pq4": QuantConfig(kind="pq4", pq_m=8, kmeans_iters=3)}[quant]
    return IndexConfig(
        dim=dim, metric=metric, index_type="ivf", n_shards=n_shards,
        ivf=IVFConfig(nlist=16, kmeans_iters=3, list_pad=16), quant=q,
        search=SearchConfig(L=48, k=K, nprobe=6))


@pytest.fixture(scope="session")
def built(sh_ds):
    """Memoizing builder: get(family, quant, n_shards); n_shards=None is
    the plain single KBest baseline."""
    cache = {}

    def get(family, quant, n_shards=None):
        key = (family, quant, n_shards)
        if key not in cache:
            cfg = _cfg(sh_ds.base.shape[1], sh_ds.metric, family, quant)
            if n_shards is None:
                cache[key] = KBest(cfg).add(sh_ds.base)
            else:
                cache[key] = ShardedKBest(cfg, n_shards=n_shards
                                          ).add(sh_ds.base)
        return cache[key]

    return get


# ------------------------------------------------- 1-shard mesh == KBest
@pytest.mark.parametrize("family,quant", [
    ("graph", "full"), ("graph", "pq4"), ("ivf", "pq"), ("ivf", "pq4")])
def test_single_shard_parity(sh_ds, built, family, quant):
    """On a 1-device mesh the sharded index reproduces KBest bit-identically
    — ids AND dists, with and without stats (the acceptance criterion)."""
    single = built(family, quant)
    sharded = built(family, quant, 1)
    d0, i0 = single.search(sh_ds.queries)
    d1, i1 = sharded.search(sh_ds.queries)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))

    d0, i0, s0 = single.search(sh_ds.queries, with_stats=True)
    d1, i1, s1 = sharded.search(sh_ds.queries, with_stats=True)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    for a, b in zip(s0, s1):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------- multi-shard recall
@pytest.mark.parametrize("family,quant", [("graph", "full"), ("ivf", "pq4")])
def test_multi_shard_recall_floor(sh_ds, built, family, quant):
    """>= 2 shards at equal per-shard L: recall@10 must be >= the single
    index (every shard runs its own full traversal — DESIGN.md §12)."""
    single = built(family, quant)
    sharded = built(family, quant, 2)
    _, i0 = single.search(sh_ds.queries)
    _, i1 = sharded.search(sh_ds.queries)
    r0 = recall_at_k(np.asarray(i0), sh_ds.gt_ids, K)
    r1 = recall_at_k(np.asarray(i1), sh_ds.gt_ids, K)
    assert r1 >= r0, (family, quant, r1, r0)
    assert r1 >= 0.8, r1     # sanity: the merge is actually searching


# ------------------------------------------------------- stats merging
def test_stats_sum_across_shards(sh_ds, built):
    """Merged stats == sum (n_hops/n_dist), AND (early_terminated), max
    (iters) of each shard's own search."""
    sharded = built("graph", "full", 2)
    _, _, st = sharded.search(sh_ds.queries, with_stats=True)
    per = [sh.search(sh_ds.queries, with_stats=True)[2]
           for sh in sharded.shards]
    assert np.array_equal(np.asarray(st.n_dist),
                          sum(np.asarray(s.n_dist) for s in per))
    assert np.array_equal(np.asarray(st.n_hops),
                          sum(np.asarray(s.n_hops) for s in per))
    et = np.logical_and.reduce([np.asarray(s.early_terminated) for s in per])
    assert np.array_equal(np.asarray(st.early_terminated), et)
    assert int(st.iters) == max(int(s.iters) for s in per)
    # merge_stats is the identity on one shard
    one = merge_stats([per[0]])
    for a, b in zip(one, per[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------- global ids + uneven shard split
def test_shard_bounds_uneven():
    assert shard_bounds(10, 3).tolist() == [0, 4, 7, 10]
    assert shard_bounds(8, 4).tolist() == [0, 2, 4, 6, 8]
    with pytest.raises(AssertionError):
        shard_bounds(2, 3)


def test_global_id_translation_uneven_shards(sh_ds):
    """P=3 over n=800 (267/267/266): returned ids must be valid GLOBAL row
    ids whose recomputed exact distance matches the returned distance —
    i.e. the offset translation points at the vectors it claims."""
    cfg = _cfg(sh_ds.base.shape[1], sh_ds.metric, "graph", "full")
    sharded = ShardedKBest(cfg, n_shards=3).add(sh_ds.base)
    assert [len(s.db) for s in sharded.shards] == [267, 267, 266]
    d, i = sharded.search(sh_ds.queries)
    d, i = np.asarray(d), np.asarray(i)
    assert ((i >= 0) & (i < N)).all()
    for row in i:                      # no cross-shard duplicate ids
        assert len(set(row.tolist())) == len(row)
    exact = -np.einsum("qd,qkd->qk", sh_ds.queries, sh_ds.base[i])  # ip
    assert np.allclose(d, exact, atol=1e-3)
    rec = recall_at_k(i, sh_ds.gt_ids, K)
    assert rec >= 0.8, rec


def test_pad_to_shard_boundary():
    db = np.arange(10 * 4, dtype=np.float32).reshape(10, 4)
    graph = np.arange(10 * 3, dtype=np.int32).reshape(10, 3) % 10
    db_p, g_p, n_local = pad_to_shard_boundary(db, graph, 4)
    assert n_local == 3 and db_p.shape == (12, 4) and g_p.shape == (12, 3)
    assert np.array_equal(db_p[:10], db) and np.array_equal(g_p[:10], graph)
    assert (db_p[10:] == 0).all() and (g_p[10:] == -1).all()
    # already even: identity
    db_e, g_e, n_l = pad_to_shard_boundary(db, graph, 5)
    assert n_l == 2 and db_e.shape == (10, 4)
    assert np.array_equal(db_e, db)


# ------------------------------------------------------------ save/load
def test_save_load_roundtrip(tmp_path, sh_ds, built):
    sharded = built("ivf", "pq4", 2)
    path = str(tmp_path / "mesh.idx")
    sharded.save(path)
    assert (tmp_path / "mesh.idx.sharded.json").exists()
    for s in range(2):
        assert (tmp_path / f"mesh.idx.shard{s}.npz").exists()
        assert (tmp_path / f"mesh.idx.shard{s}.json").exists()
    loaded = ShardedKBest.load(path)
    assert loaded.config == sharded.config
    assert np.array_equal(loaded.offsets, sharded.offsets)
    d0, i0 = sharded.search(sh_ds.queries)
    d1, i1 = loaded.search(sh_ds.queries)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


# ------------------------------------------------- padded + engine path
def test_search_padded_masks_and_parity(sh_ds, built):
    sharded = built("graph", "full", 2)
    nq = 5
    qp = np.zeros((8, sh_ds.base.shape[1]), np.float32)
    qp[:nq] = sh_ds.queries[:nq]
    mask = np.zeros((8,), bool)
    mask[:nq] = True
    d, i, st = sharded.search_padded(qp, mask, with_stats=True)
    d0, i0, st0 = sharded.search(sh_ds.queries[:nq], with_stats=True)
    assert np.array_equal(np.asarray(i)[:nq], np.asarray(i0))
    assert np.array_equal(np.asarray(d)[:nq], np.asarray(d0))
    assert (np.asarray(d)[nq:] == np.inf).all()
    assert (np.asarray(i)[nq:] == -1).all()
    assert (np.asarray(st.n_dist)[nq:] == 0).all()
    assert np.array_equal(np.asarray(st.n_dist)[:nq], np.asarray(st0.n_dist))


def test_engine_serves_sharded(sh_ds, built):
    """SearchEngine over a ShardedKBest: results match the direct sharded
    search, the cache key carries the mesh shape, and one bucket serves
    many batch sizes on a single trace."""
    from repro.serve import SearchEngine
    sharded = built("graph", "full", 2)
    eng = SearchEngine(sharded, min_bucket=8, max_bucket=16, name="mesh")
    scfg = sharded._resolve_cfg(None, None)
    assert eng._cache_key(8, scfg)[-1] == 2    # mesh shape in the key
    eng.warmup([8])
    traces = eng.n_traces
    d, i = eng.search(sh_ds.queries[:5])
    d2, i2 = eng.search(sh_ds.queries[5:12])   # different size, same bucket
    assert eng.n_traces == traces              # no re-trace inside a bucket
    d0, i0 = sharded.search(sh_ds.queries[:5])
    assert np.array_equal(np.asarray(i), np.asarray(i0))
    assert np.array_equal(np.asarray(d), np.asarray(d0))


def test_kbest_rejects_sharded_config(sh_ds):
    cfg = _cfg(sh_ds.base.shape[1], sh_ds.metric, "graph", "full",
               n_shards=2)
    with pytest.raises(AssertionError, match="ShardedKBest"):
        KBest(cfg).add(sh_ds.base)
    # and the constructor override stamps the config
    assert ShardedKBest(dataclasses.replace(cfg, n_shards=1),
                        n_shards=4).config.n_shards == 4
