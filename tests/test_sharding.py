"""Sharding rules + the device-mesh (shard_map) search path of
core/sharded.py (1-device mesh with production axis names; the 512-device
lowering is exercised by launch/dryrun.py). The device-count-agnostic
ShardedKBest subsystem has its own suite in tests/test_sharded.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_test_mesh
from repro.sharding import rules


def test_lm_param_specs_divisibility_fallback():
    mesh = make_test_mesh()   # (1, 1): every divisibility check passes
    spec = rules.lm_param_spec("layers/wq", (2, 64, 128), mesh)
    assert spec == P(None, None, "model")
    # non-divisible dims must fall back to replicated, never error
    import jax as _jax
    spec2 = rules.lm_param_spec("layers/wq", (2, 64, 127), mesh)
    assert spec2 == P(None, None, "model")  # 127 % 1 == 0 on test mesh


def test_zero1_excludes_used_axes():
    mesh = make_test_mesh()
    s = rules.zero1_state_spec(P(None, "data", None, "model"),
                               (4, 16, 32, 64), mesh)
    # "data" already used -> no duplicate axes
    flat = [a for p in s for a in (p if isinstance(p, tuple) else (p,))]
    named = [a for a in flat if a is not None]
    assert len(named) == len(set(named))


def test_param_tree_shardings_cover_all_leaves():
    from repro import configs as reg
    from repro.models.transformer import init_params
    mesh = make_test_mesh()
    cfg = reg.get("kimi_k2_1t_a32b").smoke_config()
    p = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    sh = rules.tree_param_shardings(p, mesh, "lm")
    n_leaves = len(jax.tree.leaves(p))
    n_sh = len(jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, NamedSharding)))
    assert n_leaves == n_sh
    for s, l in zip(jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, NamedSharding)),
                    jax.tree.leaves(p)):
        assert len(s.spec) <= len(l.shape)


def test_cache_shardings_long_context():
    mesh = make_test_mesh()
    cache = {"k": jax.ShapeDtypeStruct((4, 1, 512, 2, 16), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((4, 1, 512, 2, 16), jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((1,), jnp.int32)}
    sh = rules.lm_cache_shardings(cache, mesh)
    # B=1: sequence dim absorbs all axes
    assert sh["k"].spec[2] is not None


def test_distributed_search_parity(deep_ds, deep_index):
    """Sharded search over a 1-device mesh == exact top-k of local search
    on the same shard (the collective path is a no-op at P=1)."""
    from repro.core.sharded import build_sharded_search, make_sharded_arrays
    from repro.core.types import SearchConfig
    mesh = make_test_mesh()
    n = deep_index.db.shape[0]
    cfg = SearchConfig(L=48, k=10, early_term=False, n_entries=1)
    fn = build_sharded_search(mesh, cfg, "ip", n_local=n)
    db, graph, entries, queries = make_sharded_arrays(
        mesh, deep_index.db, deep_index.graph,
        jnp.array([deep_index.entry], jnp.int32),
        jnp.asarray(deep_ds.queries))
    d_sh, i_sh = fn(db, graph, entries, queries)

    from repro.core import search as smod
    dist_fn = smod.make_dist_fn(deep_index.db, "ip", "ref")
    d_loc, i_loc, _ = smod.search(
        deep_index.graph, jnp.asarray(deep_ds.queries),
        jnp.array([deep_index.entry], jnp.int32),
        dist_fn=dist_fn, cfg=cfg, n_total=n)
    assert np.array_equal(np.asarray(i_sh), np.asarray(i_loc))


def test_make_sharded_arrays_uneven_rejected_then_padded(deep_index):
    """Uneven row counts pad to the shard boundary with sentinel rows and
    the real rows round-trip bit-exactly through placement (the P=1 mesh
    exercises the assert path; pad_to_shard_boundary's P>1 arithmetic is
    covered host-side in tests/test_sharded.py)."""
    from repro.core.sharded import make_sharded_arrays
    mesh = make_test_mesh()
    db, graph, entries, queries = make_sharded_arrays(
        mesh, deep_index.db, deep_index.graph,
        jnp.array([deep_index.entry], jnp.int32),
        jnp.zeros((4, deep_index.db.shape[1]), jnp.float32))
    assert np.array_equal(np.asarray(db), np.asarray(deep_index.db))
    assert np.array_equal(np.asarray(graph), np.asarray(deep_index.graph))
