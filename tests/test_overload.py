"""Overload-safe serving (DESIGN.md §17): deadline admission control,
bounded-queue shedding, the per-request error boundary, and the
fault-injection harness itself — including the clock-skew invariance that
proves admission decisions use only relative times."""
import numpy as np
import pytest

from repro.configs import kbest as kcfg
from repro.core.index import KBest
from repro.serve import (EngineFault, FaultInjector, LatencyModel, Request,
                         STATUS_FAILED, STATUS_OK, STATUS_REJECTED,
                         STATUS_SHED, SearchEngine, serve_loop)


@pytest.fixture()
def engine():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((240, 32)).astype(np.float32)
    index = KBest(kcfg.smoke_config()).add(x)
    return SearchEngine(index, min_bucket=8, max_bucket=32)


def _reqs(engine, n, **kw):
    d = engine.index.db.shape[1]
    rng = np.random.default_rng(11)
    return [Request(queries=rng.standard_normal((4, d)).astype(np.float32),
                    request_id=i, **kw) for i in range(n)]


# ------------------------------------------------------------- admission
def test_deadline_admission_rejects_queue_busted_deadlines(engine):
    """A 1s virtual spike on request 0 makes every same-instant follower's
    50ms deadline infeasible from queue delay alone — they must be
    rejected up front, with full-shape empty results."""
    reqs = _reqs(engine, 5, arrival_ms=0.0, deadline_ms=50.0)
    rep = serve_loop(engine, reqs, coalesce=False,
                     faults=FaultInjector(latency_spikes={0: 1000.0}))
    by_id = {r.request_id: r for r in rep.results}
    assert by_id[0].status == STATUS_OK
    for i in range(1, 5):
        r = by_id[i]
        assert r.status == STATUS_REJECTED, (i, r.status)
        assert r.n_served == 0 and r.recall is None
        assert r.dists.shape == (4, 5) and np.all(np.isinf(r.dists))
        assert np.all(r.ids == -1)
    assert rep.n_rejected == 4 and rep.n_served == 4
    assert engine.stats().n_rejected == 4
    # rejections cost no service time: the served request bounds makespan
    assert rep.t_end_ms == pytest.approx(by_id[0].sojourn_ms, abs=1e-6)


def test_no_deadlines_means_no_admission_machinery(engine):
    rep = serve_loop(engine, _reqs(engine, 4))
    assert all(r.status == STATUS_OK for r in rep.results)
    assert rep.n_rejected == rep.n_shed == rep.n_failed == 0


def test_admission_false_serves_late_and_records_misses(engine):
    """admission=False is the no-policy baseline: everything is served,
    busted deadlines show up as deadline_missed, not rejections."""
    reqs = _reqs(engine, 4, arrival_ms=0.0, deadline_ms=50.0)
    rep = serve_loop(engine, reqs, coalesce=False, admission=False,
                     faults=FaultInjector(latency_spikes={0: 1000.0}))
    assert rep.n_rejected == 0
    assert all(r.status == STATUS_OK for r in rep.results)
    assert rep.n_deadline_missed >= 3
    assert engine.stats().deadline_miss_rate >= 0.75


def test_clock_skew_invariance(engine):
    """A constant arrival-clock offset must not change a single admission,
    shed, or degrade outcome — decisions are relative-time only."""
    def run(skew):
        reqs = _reqs(engine, 6, arrival_ms=0.0, deadline_ms=40.0)
        for i, r in enumerate(reqs):
            r.arrival_ms = 5.0 * i
        rep = serve_loop(engine, reqs, coalesce=False, max_queue=2,
                         faults=FaultInjector(latency_spikes={0: 300.0},
                                              skew_ms=skew))
        return [(r.request_id, r.status) for r in
                sorted(rep.results, key=lambda r: r.request_id)]
    engine.reset_stats()
    base = run(0.0)
    engine.reset_stats()
    assert run(1e7) == base
    assert any(s != STATUS_OK for _, s in base), \
        "workload too easy to exercise the policies"


# ---------------------------------------------------------- bounded queue
def test_bounded_queue_sheds_when_full(engine):
    reqs = _reqs(engine, 6, arrival_ms=0.0)
    rep = serve_loop(engine, reqs, coalesce=False, max_queue=2,
                     faults=FaultInjector(latency_spikes={0: 1000.0}))
    statuses = [r.status for r in
                sorted(rep.results, key=lambda r: r.request_id)]
    # r0 dispatches immediately, r1 queues (depth 1 at its arrival);
    # r2.. find >= 2 unfinished requests ahead and are shed
    assert statuses[:2] == [STATUS_OK, STATUS_OK]
    assert statuses[2:] == [STATUS_SHED] * 4
    assert rep.n_shed == 4 and engine.stats().n_shed == 4


# ---------------------------------------------------------- error boundary
def test_poisoned_request_fails_alone_in_coalesced_group(engine):
    """Three coalescable requests, the middle one poisoned: the group must
    be retried singly so only the poisoned request fails."""
    reqs = _reqs(engine, 3)
    rep = serve_loop(engine, reqs,
                     faults=FaultInjector(poisoned={1}))
    by_id = {r.request_id: r for r in rep.results}
    assert by_id[0].status == STATUS_OK
    assert by_id[2].status == STATUS_OK
    assert by_id[1].status == STATUS_FAILED
    assert "EngineFault" in by_id[1].error
    assert rep.n_failed == 1 and engine.stats().n_failed == 1
    assert rep.n_served == 8
    # the healthy members' answers match a direct engine search
    d, i = engine.search(np.asarray(reqs[0].queries))
    np.testing.assert_array_equal(np.asarray(by_id[0].ids), np.asarray(i))


def test_engine_exception_fails_result_not_loop(engine, monkeypatch):
    """A genuine engine-side exception (not injector-made) must also be
    boxed into the request's own result."""
    reqs = _reqs(engine, 3)
    real = SearchEngine.search
    calls = {"n": 0}

    def flaky(self, queries, k=None, search_cfg=None, gt_ids=None):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("synthetic engine crash")
        return real(self, queries, k=k, search_cfg=search_cfg, gt_ids=gt_ids)

    monkeypatch.setattr(SearchEngine, "search", flaky)
    rep = serve_loop(engine, reqs, coalesce=False)
    statuses = [r.status for r in
                sorted(rep.results, key=lambda r: r.request_id)]
    assert statuses == [STATUS_OK, STATUS_FAILED, STATUS_OK]
    assert "synthetic engine crash" in rep.results[1].error


def test_fault_injector_check_raises_only_for_poisoned():
    fi = FaultInjector(poisoned={7})
    ok = Request(queries=np.zeros((1, 4), np.float32), request_id=3)
    bad = Request(queries=np.zeros((1, 4), np.float32), request_id=7)
    fi.check([ok])
    with pytest.raises(EngineFault):
        fi.check([ok, bad])
    assert fi.extra_ms([ok, bad]) == 0.0


# ----------------------------------------------------------- latency model
def test_latency_model_calibrates_to_measurements(engine):
    m = LatencyModel(alpha=1.0)
    scfg = engine.index.config.search
    assert not m.calibrated
    m.observe(engine, scfg, 8, measured_ms=12.0)
    assert m.calibrated
    assert m.predict_ms(engine, scfg, 8) == pytest.approx(12.0, rel=1e-6)
    # unseen (config, bucket) keys borrow the global ratio: the prediction
    # scales with the cost prior instead of collapsing to the raw roofline
    wide = m.predict_ms(engine, scfg, 32)
    assert wide > 0.0 and wide != pytest.approx(12.0)


def test_latency_model_ewma_smooths(engine):
    m = LatencyModel(alpha=0.5)
    scfg = engine.index.config.search
    m.observe(engine, scfg, 8, measured_ms=10.0)
    m.observe(engine, scfg, 8, measured_ms=20.0)
    got = m.predict_ms(engine, scfg, 8)
    assert 10.0 < got < 20.0


# ------------------------------------------------------------- accounting
def test_report_counts_partition_requests(engine):
    reqs = _reqs(engine, 8, arrival_ms=0.0, deadline_ms=60.0)
    rep = serve_loop(engine, reqs, coalesce=False, max_queue=3,
                     faults=FaultInjector(latency_spikes={0: 500.0},
                                          poisoned={1}))
    n_ok = sum(r.status == STATUS_OK for r in rep.results)
    assert rep.n_requests == len(reqs)
    assert n_ok + rep.n_rejected + rep.n_shed + rep.n_failed == len(reqs)
    assert rep.n_served == 4 * n_ok
    # percentile guard: a drain where nothing is served must not raise
    engine.reset_stats()
    all_rejected = serve_loop(
        engine, _reqs(engine, 3, arrival_ms=0.0, deadline_ms=1e-6),
        coalesce=False)
    assert all_rejected.n_served == 0
    assert all_rejected.lat_p99_ms == 0.0
    assert all_rejected.sojourn_p99_ms == 0.0
