"""4-bit fast-scan PQ family (DESIGN.md §13): nibble packing, kernel-vs-ref
parity on graph and IVF paths, u8 LUT requantization bound, save/load, the
half-the-bytes memory claim, and the 50k acceptance recall floor."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ivf as ivf_mod
from repro.core import quantize as qz
from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k

RNG = np.random.default_rng(21)


def _graph_cfg(dim, metric, **qkw):
    return IndexConfig(
        dim=dim, metric=metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                          reorder="none"),
        search=SearchConfig(L=64, k=10, early_term=False),
        quant=QuantConfig(kind="pq4", kmeans_iters=5, **qkw))


# ------------------------------------------------------------------ packing
def test_pack_unpack_roundtrip():
    for n, m in [(1, 2), (7, 8), (100, 32)]:
        codes = jnp.asarray(RNG.integers(0, 16, size=(n, m)).astype(np.uint8))
        packed = qz.pq4_pack(codes)
        assert packed.shape == (n, m // 2) and packed.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(qz.pq4_unpack(packed)),
                                      np.asarray(codes))


def test_pq4_config_rejects_odd_m():
    with pytest.raises(AssertionError):
        QuantConfig(kind="pq4", pq_m=5)


# ----------------------------------------------------------- ADC semantics
def test_pq4_adc_equals_reconstructed_distance():
    """pq4 ADC must equal ||q - reconstruct(code)||^2 exactly (K=16)."""
    m, ds = 4, 8
    x = jnp.asarray(RNG.normal(size=(300, m * ds)).astype(np.float32))
    st = qz.pq_train(x, QuantConfig(kind="pq4", pq_m=m, kmeans_iters=5))
    assert st.codebooks.shape == (m, 16, ds)
    packed = qz.pq4_encode(st.codebooks, x)
    q = x[:3]
    lut = qz.pq4_query_tables(st.codebooks, q, "l2").reshape(3, m, 16)
    ids = jnp.arange(10, dtype=jnp.int32)[None].repeat(3, 0)
    from repro.kernels.ref import pq4_adc_ref
    adc = np.asarray(pq4_adc_ref(lut, packed, ids))
    books = np.asarray(st.codebooks)
    cc = np.asarray(qz.pq4_unpack(packed[:10]))
    recon = np.stack([
        np.concatenate([books[j, cc[i, j]] for j in range(m)])
        for i in range(10)])
    for qi in range(3):
        exact = ((np.asarray(q[qi])[None] - recon) ** 2).sum(1)
        np.testing.assert_allclose(adc[qi], exact, rtol=1e-4, atol=1e-4)


def test_pq4_lut_u8_requant_error_bound():
    """u8-requantized tables stay within the fast-scan bound: each of the m
    table reads moves by at most step/2, so |ADC' - ADC| <= m*step/2."""
    m, ds, Q = 8, 4, 5
    x = jnp.asarray(RNG.normal(size=(400, m * ds)).astype(np.float32))
    st = qz.pq_train(x, QuantConfig(kind="pq4", pq_m=m, kmeans_iters=5))
    packed = qz.pq4_encode(st.codebooks, x)
    q = x[:Q]
    lut = qz.pq4_query_tables(st.codebooks, q, "l2")
    lut8 = qz.pq4_requant_lut(lut)
    step = ((np.max(np.asarray(lut), axis=1) - np.min(np.asarray(lut), axis=1))
            / 255.0)
    # per-entry quantization error <= step/2
    assert np.all(np.abs(np.asarray(lut8 - lut))
                  <= step[:, None] / 2 + 1e-6)
    from repro.kernels.ref import pq4_adc_ref
    ids = jnp.asarray(RNG.integers(0, 400, size=(Q, 32)).astype(np.int32))
    a = np.asarray(pq4_adc_ref(lut.reshape(Q, m, 16), packed, ids))
    a8 = np.asarray(pq4_adc_ref(lut8.reshape(Q, m, 16), packed, ids))
    assert np.all(np.abs(a8 - a) <= m * step[:, None] / 2 + 1e-5)


# ------------------------------------------------------ kernel parity (graph)
@pytest.mark.parametrize("q,b,n,m", [(2, 9, 64, 4), (5, 17, 200, 16)])
def test_pq4_adc_kernel_vs_ref(q, b, n, m):
    from repro.kernels import ops, ref
    lut = jnp.asarray(RNG.normal(size=(q, m, 16)).astype(np.float32))
    packed = jnp.asarray(
        RNG.integers(0, 256, size=(n, m // 2)).astype(np.uint8))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.pq4_adc(lut, packed, ids)
    exp = ref.pq4_adc_ref(lut, packed, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- kernel parity (IVF)
@pytest.mark.parametrize("q,p,nlist,max_len,m,L", [
    (3, 2, 7, 24, 8, 8),
    (5, 4, 16, 40, 16, 16),
])
def test_pq4_ivf_scan_kernel_vs_ref(q, p, nlist, max_len, m, L):
    from repro.kernels import ops, ref
    luts = jnp.asarray(RNG.normal(size=(q, p, m, 16)).astype(np.float32))
    packed = jnp.asarray(
        RNG.integers(0, 256, size=(nlist, max_len, m // 2)).astype(np.uint8))
    ids = np.full((nlist, max_len), -1, np.int32)
    for c in range(nlist):
        n_valid = int(RNG.integers(0, max_len + 1))
        ids[c, :n_valid] = RNG.choice(10_000, size=n_valid, replace=False)
    ids = jnp.asarray(ids)
    probes = jnp.asarray(
        np.stack([RNG.choice(nlist, size=p, replace=False)
                  for _ in range(q)]).astype(np.int32))
    kd, ki = ops.pq4_ivf_scan(luts, packed, ids, probes, L=L)
    rd, ri = ref.pq4_ivf_scan_ref(luts, packed, ids, probes, L)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))


# ----------------------------------------------------------- end-to-end paths
def test_graph_pq4_kernel_impl_matches_ref(deep_ds):
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric, pq_m=16)
    idx = KBest(cfg).add(deep_ds.base)
    s_k = dataclasses.replace(cfg.search, dist_impl="kernel")
    d_r, i_r = idx.search(deep_ds.queries[:8], k=10)
    d_k, i_k = idx.search(deep_ds.queries[:8], k=10, search_cfg=s_k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-4, atol=1e-4)


def test_ivf_pq4_kernel_impl_matches_ref(bigann_ds):
    cfg = IndexConfig(
        dim=128, metric="l2", index_type="ivf",
        ivf=IVFConfig(nlist=32, kmeans_iters=5, list_pad=8),
        quant=QuantConfig(kind="pq4", pq_m=16, kmeans_iters=5),
        search=SearchConfig(L=64, k=10, nprobe=8))
    idx = KBest(cfg).add(bigann_ds.base)
    assert idx.ivf.packed and idx.ivf.list_codes.shape[-1] == 8
    s_k = dataclasses.replace(cfg.search, dist_impl="kernel")
    d_r, i_r = idx.search(bigann_ds.queries[:8], k=10)
    d_k, i_k = idx.search(bigann_ds.queries[:8], k=10, search_cfg=s_k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-3, atol=1e-3)


def test_graph_pq4_recall_with_rerank(deep_ds):
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric, pq_m=16)
    idx = KBest(cfg).add(deep_ds.base)
    d, i = idx.search(deep_ds.queries, k=10)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.8


def test_code_bytes_exactly_half_of_pq8_at_equal_m(deep_ds):
    m = 16
    cfg4 = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric, pq_m=m)
    cfg8 = dataclasses.replace(
        cfg4, quant=QuantConfig(kind="pq", pq_m=m, kmeans_iters=5))
    i4 = KBest(cfg4).add(deep_ds.base)
    i8 = KBest(cfg8).add(deep_ds.base)
    assert i4.pq_codes.shape[-1] * 2 == i8.pq_codes.shape[-1] == m
    assert i4.pq_codes.dtype == i8.pq_codes.dtype == jnp.uint8
    # same structural halving on the IVF list layout
    q4 = QuantConfig(kind="pq4", pq_m=m, kmeans_iters=3)
    q8 = QuantConfig(kind="pq", pq_m=m, kmeans_iters=3)
    icfg = IVFConfig(nlist=8, kmeans_iters=3, list_pad=8)
    x = jnp.asarray(deep_ds.base[:500])
    s4 = ivf_mod.build_ivf(x, icfg, q4)
    s8 = ivf_mod.build_ivf(x, icfg, q8)
    assert s4.list_codes.shape[-1] * 2 == s8.list_codes.shape[-1] == m


# save/load round-trips live in tests/test_saveload.py, parameterized
# over the whole quant registry (pq4 included, graph + IVF).


# ------------------------------------------------------------------- recall
def test_pq4_recall_50k_bigann():
    """Acceptance: pq4 recall@10 >= 0.90 on the 50k set after exact
    re-rank. pq_m=32 at 4 bits = 16 code bytes/vector — the same byte
    budget as test_ivf_recall_50k_bigann's 8-bit pq_m=16, spent on twice
    as many (coarser) subspaces, fast-scan's usual trade."""
    ds = make_dataset("bigann_like", n=50_000, n_queries=50, k=10)
    cfg = IndexConfig(
        dim=128, metric="l2", index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=10),
        quant=QuantConfig(kind="pq4", pq_m=32, kmeans_iters=10),
        search=SearchConfig(L=384, k=10, nprobe=48))
    idx = KBest(cfg).add(ds.base)
    _, ids = idx.search(ds.queries, k=10)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert rec >= 0.90, rec
