"""The §Perf optimization variants must be NUMERICALLY equivalent to their
baselines (same math, different layout/schedule)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as reg


def test_lm_sharded_loss_matches_baseline():
    """loss_vocab_axis path == naive path (same logits, different softmax
    factorization) on a 1-device mesh."""
    from repro.launch.mesh import make_test_mesh, mesh_context
    from repro.models import transformer as T
    cfg = reg.get("gemma_2b").smoke_config()
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                          cfg.vocab)}
    l0, _ = T.loss_fn(p, batch, cfg)
    cfg2 = dataclasses.replace(cfg, loss_vocab_axis="model",
                               loss_batch_axes=("data",),
                               loss_vocab_shards=2)
    mesh = make_test_mesh()
    with mesh_context(mesh):
        l1, _ = jax.jit(lambda p, b: T.loss_fn(p, b, cfg2))(p, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-5)


def test_bert4rec_masked_loss_matches_full():
    """masked_positions path == full loss when P covers all masked slots."""
    from repro.models import recsys as R
    cfg = reg.get("bert4rec").smoke_config()
    p = R.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, S = 4, cfg.seq_len
    seq = jax.random.randint(key, (B, S), 1, cfg.n_items)
    labels = jnp.where(jax.random.bernoulli(key, 0.2, (B, S)),
                       seq, -1).astype(jnp.int32)
    batch = {"seq": jnp.where(labels >= 0, 0, seq), "labels": labels}
    l0, _ = R.loss_fn(p, batch, cfg)
    cfg2 = dataclasses.replace(cfg, masked_positions=S)  # covers everything
    l1, _ = R.loss_fn(p, batch, cfg2)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_retrieval_shardmap_matches_naive():
    from repro.launch.mesh import make_test_mesh, mesh_context
    from repro.models import recsys as R
    cfg = reg.get("bst").smoke_config()
    p = R.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"hist": jax.random.randint(jax.random.PRNGKey(1),
                                        (3, cfg.seq_len), 0, cfg.n_items)}
    d0, i0 = R.serve_retrieval(p, batch, cfg, k=7)
    mesh = make_test_mesh()
    d1, i1 = R.serve_retrieval_shardmap(p, batch, cfg, mesh, k=7)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_moe_ep_constraints_nop_without_axes():
    """ep_axis=\"\" must leave moe_ffn usable with no mesh at all."""
    from repro.layers.moe import MoEConfig, init_moe, moe_ffn
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16)
    p = init_moe(jax.random.PRNGKey(0), 8, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 8))
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape and jnp.isfinite(aux)


def test_moe_keeps_dtype_bf16():
    """the f32-poisoning regression guard (EXPERIMENTS §Perf, H-A2)."""
    from repro.layers.moe import MoEConfig, init_moe, moe_ffn
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16)
    p = init_moe(jax.random.PRNGKey(0), 8, cfg, dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 8)).astype(jnp.bfloat16)
    out, _ = moe_ffn(p, x, cfg)
    assert out.dtype == jnp.bfloat16


def test_dimenet_remat_matches():
    from repro.data.pipeline import gnn_minibatches
    from repro.models import dimenet as D
    cfg = reg.get("dimenet").smoke_config()
    p = D.init_params(cfg, jax.random.PRNGKey(0))
    it = gnn_minibatches(n_nodes=200, d_feat=cfg.d_feat, batch_nodes=4,
                         fanouts=(3, 2), n_classes=cfg.n_out, triplet_cap=4)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    l0, _ = D.loss_fn(p, batch, cfg)
    cfg2 = dataclasses.replace(cfg, remat=True)
    l1, _ = D.loss_fn(p, batch, cfg2)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


def test_lm_remat_policies_match():
    from repro.models import transformer as T
    cfg = dataclasses.replace(reg.get("qwen2_5_14b").smoke_config(),
                              remat=True)
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0,
                                          cfg.vocab)}
    l0, _ = T.loss_fn(p, batch, cfg)
    for pol in ("dots", "dots_nb"):
        c = dataclasses.replace(cfg, remat_policy=pol)
        l1, _ = T.loss_fn(p, batch, c)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6), pol


def test_moe_shardmap_matches_reference():
    """Explicit-collective MoE (hillclimb A5) == reference moe_ffn, forward
    and gradients, on a 2x2 device mesh (needs no-drop capacity so the
    per-column capacity split cannot change the drop pattern)."""
    import os
    if len(jax.devices()) < 4:
        import pytest
        pytest.skip("needs 4 host devices (run tests with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    from repro.layers.moe import MoEConfig, init_moe, moe_ffn, moe_ffn_shardmap
    from repro.launch.mesh import mesh_context
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg0 = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    cfg1 = dataclasses.replace(cfg0, ep_axis="data", tp_axis="model",
                               token_axes=("data",), use_shardmap=True,
                               ep_size=2, tp_size=2)
    p = init_moe(jax.random.PRNGKey(0), 8, cfg0, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    out0, _ = moe_ffn(p, x, cfg0)
    with mesh_context(mesh):
        out1, _ = jax.jit(lambda p, x: moe_ffn_shardmap(p, x, cfg1))(p, x)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               rtol=1e-5, atol=1e-5)
