"""Tuner coverage (DESIGN.md §16): the paper's ET dry-run procedure,
the quant-kind sweep, and the model-guided full-knob tuner.

The ET/memo tests stub `tune._eval` with a counting fake — the
procedure's contract (admissibility floor, no duplicate measurements)
is about WHICH configs get evaluated, not about search quality, so no
index is built. The quant-kind and full-knob tests run real builds at
sizes the 50k-build memory note rules IN (<= 5k)."""
import dataclasses

import numpy as np
import pytest

from repro.core import tune
from repro.core.types import QUANT_KINDS, SearchConfig
from repro.data.vectors import exact_topk, make_dataset


# ----------------------------------------------------------- memoization

def test_memo_eval_collapses_duplicate_configs(monkeypatch):
    calls = []

    def fake_eval(index, queries, gt_ids, scfg):
        calls.append(scfg)
        return 0.9, 10.0

    monkeypatch.setattr(tune, "_eval", fake_eval)
    ev = tune._memo_eval(None, None, None)
    a = SearchConfig(L=64, k=10)
    b = SearchConfig(L=64, k=10)          # equal frozen config, new object
    c = SearchConfig(L=128, k=10)
    assert ev(a) == ev(b) == (0.9, 10.0)
    ev(c)
    ev(a)
    assert len(calls) == 2                # one per DISTINCT config
    assert set(ev.cache) == {a, c}


# ------------------------------------------------------- early-term stage

def test_tune_early_term_floor_and_no_duplicate_measures(monkeypatch):
    """The tuned config must be admissible (recall within slack of the
    no-ET baseline) and cheaper; the memoized evaluator must never
    measure the same config twice across the t_frac binary searches."""
    calls = []

    def fake_eval(index, queries, gt_ids, scfg):
        calls.append(scfg)
        if not scfg.early_term:
            return 0.96, 100.0
        # admissible once patience >= 8; cheaper at lower patience
        rec = 0.96 if scfg.et_patience >= 8 else 0.50
        return rec, 40.0 + scfg.et_patience
    monkeypatch.setattr(tune, "_eval", fake_eval)

    base = SearchConfig(L=64, k=10)
    tuned = tune.tune_early_term(None, None, None, base,
                                 recall_target=0.95)
    assert tuned.early_term and tuned.et_patience == 8
    # admissibility floor: the choice itself meets it
    rec, hops = fake_eval(None, None, None, tuned)
    assert rec >= min(0.95, 0.96) - 0.005
    assert hops < 100.0
    # memoized evaluator => every measured config is distinct
    assert len(calls) == len(set(calls)) + 1   # +1: the re-check above


# ------------------------------------------------------- quant-kind sweep

def test_tune_quant_kind_covers_registry():
    from repro.core import quantize as qz
    from repro.core.index import KBest
    from repro.configs import kbest as kcfg

    rng = np.random.default_rng(0)
    x = rng.standard_normal((400, 32)).astype(np.float32)
    q = rng.standard_normal((16, 32)).astype(np.float32)
    gt = exact_topk(x, q, k=5, metric="l2")
    idx = KBest(kcfg.smoke_config()).add(x)

    best, rows = tune.tune_quant_kind(idx, q, gt, recall_target=0.6,
                                      pq_m=16)
    variants = qz.quant_variants(pq_m=16)
    assert {r["quant"] for r in rows} == set(variants)
    assert best in variants
    # the swept registry spans every registered kind
    assert {v["kind"] for v in variants.values()} == set(QUANT_KINDS)


# ------------------------------------------------- model-guided full tuner

@pytest.fixture(scope="module")
def tuned_ivf():
    ds = make_dataset("deep_like", n=5_000, n_queries=200, k=10)
    return tune.tune_config(ds.base, ds.queries, ds.gt_ids,
                            metric=ds.metric, index_type="ivf", k=10,
                            recall_slo=0.80)


def test_tune_config_prunes_at_least_half_the_grid(tuned_ivf):
    res = tuned_ivf
    assert res.grid_size >= 12, "grid too small to exercise pruning"
    assert res.n_measured <= res.grid_size // 2
    assert res.n_pruned >= res.grid_size - res.grid_size // 2
    assert res.n_measured == len(res.rows) > 0
    # rows really are cheapest-first (model ordering drove measurement)
    preds = [r["pred_us"] for r in res.rows]
    assert preds == sorted(preds)


def test_tune_config_meets_slo_on_holdout(tuned_ivf):
    res = tuned_ivf
    assert res.recall_tune >= res.recall_slo, res.notes
    assert res.recall_holdout >= res.recall_slo, \
        (res.recall_holdout, res.notes)
    cfg = res.config
    assert cfg.index_type == "ivf" and cfg.search.k == 10
    # the emitted kind comes from the registry the tuner swept
    from repro.core import quantize as qz
    assert cfg.quant.kind in qz.IVF_QUANT_KINDS
