"""Vector quantization (A4): PQ / SQ correctness and quantized search."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.core.types import QuantConfig, SearchConfig
from repro.data.vectors import recall_at_k


def test_kmeans_reduces_distortion():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(500, 8)).astype(np.float32))
    c1 = qz.kmeans(x, 16, iters=1)
    c10 = qz.kmeans(x, 16, iters=10)

    def distortion(c):
        d = (jnp.sum(x * x, 1)[:, None] + jnp.sum(c * c, 1)[None]
             - 2 * x @ c.T)
        return float(jnp.mean(jnp.min(d, axis=1)))
    assert distortion(c10) <= distortion(c1) + 1e-5


def test_pq_adc_equals_reconstructed_distance():
    """ADC(q, code) must equal ||q - reconstruct(code)||^2 exactly."""
    rng = np.random.default_rng(1)
    m, ds = 4, 8
    x = jnp.asarray(rng.normal(size=(300, m * ds)).astype(np.float32))
    st = qz.pq_train(x, QuantConfig(kind="pq", pq_m=m, kmeans_iters=5))
    codes = qz.pq_encode(st.codebooks, x)
    q = x[:3]
    lut = qz.pq_query_tables(st.codebooks, q, "l2").reshape(3, m, 256)
    ids = jnp.arange(10, dtype=jnp.int32)[None].repeat(3, 0)
    from repro.kernels.ref import pq_adc_ref
    adc = np.asarray(pq_adc_ref(lut, codes, ids))
    # reconstruct and compare
    books = np.asarray(st.codebooks)
    cc = np.asarray(codes[:10]).astype(int)
    recon = np.stack([
        np.concatenate([books[j, cc[i, j]] for j in range(m)])
        for i in range(10)])
    for qi in range(3):
        exact = ((np.asarray(q[qi])[None] - recon) ** 2).sum(1)
        np.testing.assert_allclose(adc[qi], exact, rtol=1e-4, atol=1e-4)


def test_pq_search_recall_with_rerank(deep_ds):
    from repro.core.index import KBest
    from repro.core.types import BuildConfig, IndexConfig
    cfg = IndexConfig(
        dim=deep_ds.base.shape[1], metric=deep_ds.metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=1,
                          refine_cands=64),
        search=SearchConfig(L=64, k=10, early_term=False),
        quant=QuantConfig(kind="pq", pq_m=8, kmeans_iters=5))
    idx = KBest(cfg).add(deep_ds.base)
    d, i = idx.search(deep_ds.queries, k=10)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.8


def test_sq_search_recall(deep_ds):
    from repro.core.index import KBest
    from repro.core.types import BuildConfig, IndexConfig
    cfg = IndexConfig(
        dim=deep_ds.base.shape[1], metric=deep_ds.metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=1,
                          refine_cands=64),
        search=SearchConfig(L=64, k=10, early_term=False),
        quant=QuantConfig(kind="sq"))
    idx = KBest(cfg).add(deep_ds.base)
    d, i = idx.search(deep_ds.queries, k=10)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.9


def test_sq_kernel_impl_routes_through_kernel_and_matches(monkeypatch):
    """sq_make_dist_fn used to IGNORE impl — dist_impl="kernel" SQ runs
    were the ref path mislabeled under a ("sq", "kernel") cache key. The
    kernel impl must now actually call the fused sq_gather_dist kernel and
    agree with the ref path."""
    import repro.kernels.ops as kops

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(200, 32)).astype(np.float32))
    st = qz.sq_train(x)
    codes = qz.sq_encode(st, x)
    q = x[:4]
    ids = jnp.asarray(rng.integers(-1, 200, size=(4, 9)).astype(np.int32))

    called = {}
    real = kops.sq_gather_dist

    def spy(*a, **kw):
        called["kernel"] = True
        return real(*a, **kw)

    monkeypatch.setattr(kops, "sq_gather_dist", spy)
    for metric in ("l2", "ip"):
        called.clear()
        out_r = qz.sq_make_dist_fn(codes, st, metric, impl="ref")(q, ids)
        assert "kernel" not in called
        out_k = qz.sq_make_dist_fn(codes, st, metric, impl="kernel")(q, ids)
        assert called.get("kernel"), "impl='kernel' must hit the kernel path"
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=3e-5, atol=3e-4)


def test_pq_ip_tables():
    """IP LUTs: sum over subspaces == -<q, reconstruction>."""
    rng = np.random.default_rng(2)
    m, ds = 4, 4
    x = jnp.asarray(rng.normal(size=(300, m * ds)).astype(np.float32))
    st = qz.pq_train(x, QuantConfig(kind="pq", pq_m=m, kmeans_iters=5))
    codes = qz.pq_encode(st.codebooks, x)
    lut = qz.pq_query_tables(st.codebooks, x[:2], "ip").reshape(2, m, 256)
    from repro.kernels.ref import pq_adc_ref
    ids = jnp.arange(5, dtype=jnp.int32)[None].repeat(2, 0)
    adc = np.asarray(pq_adc_ref(lut, codes, ids))
    books = np.asarray(st.codebooks)
    cc = np.asarray(codes[:5]).astype(int)
    recon = np.stack([np.concatenate([books[j, cc[i, j]] for j in range(m)])
                      for i in range(5)])
    for qi in range(2):
        exact = -(recon @ np.asarray(x[qi]))
        np.testing.assert_allclose(adc[qi], exact, rtol=1e-4, atol=1e-4)
