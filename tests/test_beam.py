"""Beam-parallel traversal (DESIGN.md §2): W=1 bit-parity against a port of
the seed (single-expansion, full-argsort) traversal, sorted-merge vs argsort
oracle equivalence, pick_top_w / dedupe properties, per-expansion ET
ordering, padded-lane and batch_B guarantees, and the serving cache key."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.core import queue as qmod
from repro.core import search as search_mod
from repro.core.index import KBest, _widen, _widen_bin
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset

# Every registered quant kind (derived from THE registry so a new kind
# lands in the beam parity sweep automatically — kbest-lint flags
# hand-enumerated kind lists).
QUANTS = tuple(dict.fromkeys(
    kw["kind"] for kw in qz.quant_variants().values()))


# --------------------------------------------------------------------------
# The seed traversal, ported verbatim (pre-beam semantics): one expansion
# per iteration, masked-argmin pick, full stable-argsort merge. This is the
# parity anchor — every W=1 search must reproduce it bit-for-bit.
# --------------------------------------------------------------------------
def _seed_merge_insert(q, new_dists, new_ids):
    L = q.dists.shape[0]
    in_queue = jnp.any(new_ids[:, None] == q.ids[None, :], axis=1)
    m = new_ids.shape[0]
    dup_prior = jnp.any(
        (new_ids[:, None] == new_ids[None, :])
        & (jnp.arange(m)[None, :] < jnp.arange(m)[:, None]), axis=1)
    bad = in_queue | dup_prior | (new_ids < 0)
    nd = jnp.where(bad, jnp.inf, new_dists)
    ni = jnp.where(bad, -1, new_ids)
    cat_d = jnp.concatenate([q.dists, nd])
    cat_i = jnp.concatenate([q.ids, ni])
    cat_v = jnp.concatenate([q.visited, jnp.zeros_like(ni, dtype=bool)])
    order = jnp.argsort(cat_d, stable=True)
    sd, si, sv = cat_d[order], cat_i[order], cat_v[order]
    out = qmod.Queue(dists=sd[:L], ids=si[:L], visited=sv[:L])
    best_new = jnp.min(nd)
    better = jnp.sum(cat_d < best_new) + jnp.sum(q.dists == best_new)
    best_rank = jnp.where(jnp.isinf(best_new), L,
                          jnp.minimum(better, L)).astype(jnp.int32)
    return out, best_rank


def _seed_pick(q):
    masked = jnp.where(q.visited, jnp.inf, q.dists)
    idx = jnp.argmin(masked).astype(jnp.int32)
    return idx, jnp.isfinite(masked[idx])


def _seed_search(graph, queries, entry_ids, dist_fn, cfg, n_total,
                 valid_mask=None):
    Q = queries.shape[0]
    L, k = cfg.L, cfg.k
    t_pos = jnp.int32(int(cfg.et_t_frac * L))
    W = (n_total + 31) // 32 if cfg.visited_mode == "bitmap" else 1

    e_ids = jnp.broadcast_to(entry_ids[None, :], (Q, entry_ids.shape[0]))
    e_dists = dist_fn(queries, e_ids)
    q0 = qmod.init_queue(L)
    q0 = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (Q,) + x.shape), q0)
    queue = jax.vmap(lambda qq, nd, ni: _seed_merge_insert(qq, nd, ni)[0])(
        qmod.Queue(q0[0], q0[1], q0[2]), e_dists, e_ids)
    bitmap = jnp.zeros((Q, W), dtype=jnp.uint32)
    if cfg.visited_mode == "bitmap":
        bitmap = jax.vmap(search_mod._bitmap_set)(bitmap, e_ids)
    active0 = (jnp.ones((Q,), bool) if valid_mask is None
               else valid_mask.astype(bool))
    n_seed = jnp.where(active0, jnp.sum(e_ids >= 0, axis=1), 0).astype(jnp.int32)
    carry = (queue.dists, queue.ids, queue.visited, bitmap,
             jnp.zeros((Q,), jnp.int32), jnp.zeros((Q,), bool), active0,
             jnp.zeros((Q,), jnp.int32), n_seed, jnp.int32(0))

    def cond(c):
        return jnp.any(c[6]) & (c[9] < cfg.hops_bound)

    def body(c):
        (cd, ci, cv, bitmap, et_ctr, fired, active, hops, ndist, it) = c
        queue = qmod.Queue(cd, ci, cv)
        idx, has = jax.vmap(_seed_pick)(queue)
        expand = active & has
        v = jnp.where(expand, queue.ids[jnp.arange(Q), idx], -1)
        vis = jax.vmap(lambda qq, i, do: qq.visited.at[i].set(
            jnp.where(do, True, qq.visited[i])))(queue, idx, expand)
        queue = queue._replace(visited=vis)
        nbrs = jnp.where(v[:, None] >= 0, graph[jnp.maximum(v, 0)], -1)
        m = nbrs.shape[1]
        dup = jnp.any((nbrs[:, :, None] == nbrs[:, None, :])
                      & (jnp.arange(m)[None, None, :]
                         < jnp.arange(m)[None, :, None]), axis=2)
        nbrs = jnp.where(dup | (nbrs < 0), -1, nbrs)
        if cfg.visited_mode == "bitmap":
            seen = jax.vmap(search_mod._bitmap_test)(bitmap, nbrs)
            nbrs = jnp.where(seen, -1, nbrs)
            bitmap = jax.vmap(search_mod._bitmap_set_raw)(bitmap, nbrs)
        n_new = jnp.sum(nbrs >= 0, axis=1).astype(jnp.int32)
        # semantically a no-op (merge_insert discards in-queue dups anyway,
        # and n_new is counted above, as the seed counted it): masking
        # before the distance call keeps this port's XLA program fused the
        # same way as the refactored loop, so dists compare BIT-exact
        # instead of to within codegen reassociation ulps
        in_q = jnp.any(nbrs[:, :, None] == queue.ids[:, None, :], axis=2)
        nbrs = jnp.where(in_q & (nbrs >= 0), -1, nbrs)
        nd = dist_fn(queries, nbrs)
        nd = jnp.where(nbrs >= 0, nd, jnp.inf)
        merged, best_rank = jax.vmap(_seed_merge_insert)(queue, nd, nbrs)
        queue = jax.tree.map(
            lambda new, old: jnp.where(
                expand.reshape((Q,) + (1,) * (new.ndim - 1)), new, old),
            merged, queue)
        beyond = best_rank > t_pos
        et_ctr = jnp.where(expand, jnp.where(beyond, et_ctr + 1, 0), et_ctr)
        fired = fired | (cfg.early_term & expand & (et_ctr >= cfg.et_patience))
        hops = hops + expand.astype(jnp.int32)
        ndist = ndist + jnp.where(expand, n_new, 0)
        active = active & has & ~fired & (hops < cfg.hops_bound)
        return (queue.dists, queue.ids, queue.visited, bitmap, et_ctr,
                fired, active, hops, ndist, it + 1)

    out = jax.lax.while_loop(cond, body, carry)
    final = qmod.Queue(out[0], out[1], out[2])
    dists_k, ids_k = jax.vmap(lambda q: qmod.topk(q, k))(final)
    return dists_k, ids_k, search_mod.SearchStats(out[7], out[8], out[5],
                                                  out[9])


# --------------------------------------------------------------------------
# Fixtures: one small dataset, one index per quant family
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ds():
    return make_dataset("deep_like", n=800, n_queries=16, k=10)


def _index(ds, quant):
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=10, knn_k=16, builder="brute", refine_iters=1,
                          refine_cands=24, reorder="mst"),
        quant=QuantConfig(kind=quant, pq_m=16, kmeans_iters=3),
        search=SearchConfig(L=24, k=8, early_term=True, et_patience=8))
    return KBest(cfg).add(ds.base)


@pytest.fixture(scope="module")
def indexes(ds):
    return {q: _index(ds, q) for q in QUANTS}


def _traversal_operands(idx, scfg, queries):
    """The (graph, queries-operand, entry_ids, dist_fn, cfg) a KBest search
    hands to core.search for its quant family (white-box, mirrors
    _search_impl so the seed port can be driven identically)."""
    from repro.core import quantize as qz
    ds_q = jnp.asarray(queries)
    cfg = idx.config
    metric = "ip" if cfg.metric == "cosine" else cfg.metric
    quant = cfg.quant.kind
    if quant == "none":
        return idx.graph, ds_q, idx._entry_ids(scfg.n_entries,
                                               idx.db.shape[0]), \
            idx._get_dist_fn("full", "ref"), scfg
    if quant == "pq":
        op = qz.pq_query_tables(idx.pq.codebooks, ds_q, metric)
    elif quant == "pq4":
        op = qz.pq4_query_tables(idx.pq.codebooks, ds_q, metric)
    elif quant == "bin":
        op = qz.bin_query_codes(idx.bin, ds_q)
    else:
        op = ds_q
    widen = _widen_bin if quant == "bin" else _widen
    return idx.graph, op, idx._entry_ids(scfg.n_entries, idx.db.shape[0]), \
        idx._get_dist_fn(quant if quant != "none" else "full", "ref"), \
        widen(scfg)


@pytest.mark.parametrize("visited_mode", ["queue", "bitmap"])
@pytest.mark.parametrize("quant", QUANTS)
def test_w1_bit_parity_vs_seed(indexes, ds, quant, visited_mode):
    """beam_width=1 must reproduce the seed traversal bit-for-bit — dists,
    ids and every stat — for every quant family and visited mode."""
    idx = indexes[quant]
    scfg = dataclasses.replace(idx.config.search, visited_mode=visited_mode)
    graph, op, entries, dist_fn, cfg = _traversal_operands(idx, scfg,
                                                           ds.queries)
    n = idx.db.shape[0]
    d0, i0, st0 = _seed_search(graph, op, entries, dist_fn, cfg, n)
    d1, i1, st1 = search_mod.search(graph, op, entries, dist_fn=dist_fn,
                                    cfg=cfg, n_total=n)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    for a, b in zip(st0, st1):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    if quant == "none":
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
    else:
        # the seed PORT is a separately-compiled XLA program, and XLA may
        # reassociate the fused ADC-sum reduction differently across
        # programs — the traversal itself is bit-faithful (ids, every stat,
        # and the full-precision dists above are exact; the true pre-PR
        # binary matched bit-for-bit at refactor time), so quantized dists
        # get a last-ulp budget, not a semantic tolerance
        f0, f1 = np.asarray(d0), np.asarray(d1)
        assert np.array_equal(np.isfinite(f0), np.isfinite(f1))
        m = np.isfinite(f0)
        np.testing.assert_array_max_ulp(f0[m], f1[m], maxulp=4)


@pytest.mark.parametrize("quant", QUANTS)
def test_w1_bit_parity_facade_with_and_without_stats(indexes, ds, quant):
    """KBest-level W=1 explicit beam config == default config, stats or
    not (the whole pipeline incl. re-rank is beam-invariant at W=1)."""
    idx = indexes[quant]
    s1 = dataclasses.replace(idx.config.search, beam_width=1)
    d0, i0 = idx.search(ds.queries, search_cfg=idx.config.search)
    d1, i1, st = idx.search(ds.queries, search_cfg=s1, with_stats=True)
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.asarray(st.n_dist).min() > 0


@pytest.mark.parametrize("quant", ["pq", "pq4"])
def test_ivf_beam_invariant(ds, quant):
    """IVF has no traversal loop: any beam_width must give identical
    results and stats (the beam knob only shapes the graph family)."""
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(nlist=12, kmeans_iters=3, list_pad=16),
        quant=QuantConfig(kind=quant, pq_m=16, kmeans_iters=3),
        search=SearchConfig(L=24, k=8, nprobe=4))
    idx = KBest(cfg).add(ds.base)
    d1, i1, s1 = idx.search(ds.queries, with_stats=True)
    s4 = dataclasses.replace(cfg.search, beam_width=4)
    d4, i4, st4 = idx.search(ds.queries, search_cfg=s4, with_stats=True)
    assert np.array_equal(np.asarray(d1), np.asarray(d4))
    assert np.array_equal(np.asarray(i1), np.asarray(i4))
    for a, b in zip(s1, st4):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Beam semantics
# --------------------------------------------------------------------------
@pytest.mark.parametrize("quant", QUANTS)
def test_beam_cuts_iterations(indexes, ds, quant):
    """The tentpole claim at test scale: W=4 needs >= 1.5x fewer lockstep
    iterations than W=1 with recall intact (benchmarks/traverse.py sweeps
    the full curve)."""
    idx = indexes[quant]
    base = dataclasses.replace(idx.config.search, early_term=False)
    _, i1, s1 = idx.search(ds.queries, search_cfg=base, with_stats=True)
    s = dataclasses.replace(base, beam_width=4)
    _, i4, s4 = idx.search(ds.queries, search_cfg=s, with_stats=True)
    assert int(s1.iters) >= 1.5 * int(s4.iters), (int(s1.iters),
                                                  int(s4.iters))
    from repro.data.vectors import recall_at_k
    r1 = recall_at_k(np.asarray(i1), ds.gt_ids, 8)
    r4 = recall_at_k(np.asarray(i4), ds.gt_ids, 8)
    assert r4 >= r1 - 0.02, (r1, r4)


def test_beam_kernel_matches_ref(indexes, ds):
    """W>1 with dist_impl=kernel routes through fused_expand; results and
    distance counts must match the ref path exactly."""
    for quant in QUANTS:
        idx = indexes[quant]
        s = dataclasses.replace(idx.config.search, beam_width=3,
                                early_term=False)
        d0, i0, st0 = idx.search(ds.queries[:6], search_cfg=s,
                                 with_stats=True)
        sk = dataclasses.replace(s, dist_impl="kernel")
        d1, i1, st1 = idx.search(ds.queries[:6], search_cfg=sk,
                                 with_stats=True)
        assert np.array_equal(np.asarray(i0), np.asarray(i1)), quant
        assert np.array_equal(np.asarray(st0.n_dist),
                              np.asarray(st1.n_dist)), quant


def test_et_fires_no_later_under_beam(indexes, ds):
    """Eq. 3 in beam order: ET fires no later than W=1 on the lockstep
    clock. Per lane, a beam lane that fires does so within ceil(hops/W)+1
    iterations, which must not exceed the W=1 lane's firing iteration
    (== its hops); the batch critical path shrinks with it. (Expansion
    COUNTS may grow — the beam deliberately trades cheap extra expansions
    for fewer iterations, and a lane may even exhaust its queue before the
    patience threshold — so the clock, not the hop count, is the
    no-later guarantee.)"""
    idx = indexes["none"]
    base = dataclasses.replace(idx.config.search, early_term=True,
                               et_patience=6, L=24)
    _, _, s1 = idx.search(ds.queries, search_cfg=base, with_stats=True)
    fired1 = np.asarray(s1.early_terminated)
    assert fired1.any(), "workload must ET-fire"
    for W in (2, 4):
        s = dataclasses.replace(base, beam_width=W)
        _, _, sw = idx.search(ds.queries, search_cfg=s, with_stats=True)
        assert int(sw.iters) <= int(s1.iters)
        firedw = np.asarray(sw.early_terminated)
        assert firedw.any(), "beam must not disable ET"
        both = fired1 & firedw
        it1 = np.asarray(s1.n_hops)[both]            # 1 hop == 1 iteration
        itw = -(-np.asarray(sw.n_hops)[both] // W) + 1
        assert np.all(itw <= it1), (W, itw, it1)


def test_padded_lanes_free_under_beam(indexes, ds):
    """search_padded under W=4: invalid lanes add zero distance
    computations and valid lanes are bit-identical to the unpadded call."""
    idx = indexes["none"]
    s = dataclasses.replace(idx.config.search, beam_width=4)
    Qv = 10
    qp = np.zeros((16, ds.base.shape[1]), np.float32)
    qp[:Qv] = ds.queries[:Qv]
    vm = np.zeros((16,), bool)
    vm[:Qv] = True
    dp, ip_, stp = idx.search_padded(qp, vm, search_cfg=s, with_stats=True)
    d, i, st = idx.search(ds.queries[:Qv], search_cfg=s, with_stats=True)
    assert np.array_equal(np.asarray(dp)[:Qv], np.asarray(d))
    assert np.array_equal(np.asarray(ip_)[:Qv], np.asarray(i))
    assert np.all(np.asarray(stp.n_dist)[Qv:] == 0)
    assert np.all(np.asarray(stp.n_hops)[Qv:] == 0)
    assert np.array_equal(np.asarray(stp.n_dist)[:Qv], np.asarray(st.n_dist))


def test_batch_B_chunking_identical(indexes, ds):
    """SearchConfig.batch_B chunks the W·M distance calls without changing
    the search: identical candidate sets/order and identical work counts.
    (Distance BITS may drift a few ulp — XLA vectorizes the per-candidate
    reduction differently at different call shapes, exactly as real
    hardware tiles would — so dists compare at ulp, ids and stats exactly.)"""
    idx = indexes["none"]
    for W in (1, 4):
        s = dataclasses.replace(idx.config.search, beam_width=W)
        d0, i0, st0 = idx.search(ds.queries, search_cfg=s, with_stats=True)
        for B in (4, 7, 64):
            sb = dataclasses.replace(s, batch_B=B)
            d1, i1, st1 = idx.search(ds.queries, search_cfg=sb,
                                     with_stats=True)
            np.testing.assert_array_max_ulp(np.asarray(d0), np.asarray(d1),
                                            maxulp=4)
            assert np.array_equal(np.asarray(i0), np.asarray(i1)), (W, B)
            assert np.array_equal(np.asarray(st0.n_dist),
                                  np.asarray(st1.n_dist)), (W, B)
        # kernel impl honors batch_B by falling back to chunked dist calls
        sbk = dataclasses.replace(s, batch_B=8, dist_impl="kernel")
        d2, i2 = idx.search(ds.queries, search_cfg=sbk)
        assert np.array_equal(np.asarray(i0), np.asarray(i2)), W


def test_beam_width_validation():
    with pytest.raises(AssertionError):
        SearchConfig(L=8, k=4, beam_width=0)
    with pytest.raises(AssertionError):
        SearchConfig(L=8, k=4, beam_width=9)   # > L
    with pytest.raises(AssertionError):
        SearchConfig(L=8, k=4, batch_B=-1)


def test_sharded_beam_parity(ds):
    """1-shard ShardedKBest at W=4 stays bit-identical to plain KBest."""
    from repro.core.sharded import ShardedKBest
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=10, knn_k=16, builder="brute", refine_iters=1,
                          refine_cands=24),
        search=SearchConfig(L=24, k=8, beam_width=4, early_term=False))
    a = KBest(cfg).add(ds.base)
    b = ShardedKBest(cfg, n_shards=1).add(ds.base)
    da, ia, sa = a.search(ds.queries, with_stats=True)
    db_, ib, sb = b.search(ds.queries, with_stats=True)
    assert np.array_equal(np.asarray(da), np.asarray(db_))
    assert np.array_equal(np.asarray(ia), np.asarray(ib))
    assert np.array_equal(np.asarray(sa.n_dist), np.asarray(sb.n_dist))


def test_engine_cache_keys_on_beam_width(indexes, ds):
    """A changed beam_width is a different XLA program: new trace; the same
    beam_width re-serves from cache without retracing."""
    from repro.serve.engine import SearchEngine
    eng = SearchEngine(indexes["none"], min_bucket=8, max_bucket=16)
    s2 = dataclasses.replace(indexes["none"].config.search, beam_width=2)
    s4 = dataclasses.replace(indexes["none"].config.search, beam_width=4)
    eng.search(ds.queries[:5], search_cfg=s2)
    t = eng.n_traces
    eng.search(ds.queries[:5], search_cfg=s2)
    assert eng.n_traces == t, "same beam_width must not retrace"
    eng.search(ds.queries[:5], search_cfg=s4)
    assert eng.n_traces == t + 1, "new beam_width must be a new program"


# --------------------------------------------------------------------------
# Queue primitives: each property is a plain checker, driven BOTH by a
# seeded sweep (always runs — this container has no hypothesis) and by
# hypothesis when available (CI installs it; same profile as test_property).
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("beam", max_examples=25, deadline=None)
    settings.load_profile("beam")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_queue(r, L):
    n_filled = int(r.integers(0, L + 1))
    dists = np.full(L, np.inf, np.float32)
    ids = np.full(L, -1, np.int64)
    dists[:n_filled] = r.normal(size=n_filled).astype(np.float32)
    ids[:n_filled] = r.choice(10_000, size=n_filled, replace=False)
    vis = np.ones(L, bool)
    vis[:n_filled] = r.random(n_filled) < 0.5
    order = np.argsort(dists, kind="stable")
    return qmod.Queue(jnp.asarray(dists[order], jnp.float32),
                      jnp.asarray(ids[order], jnp.int32),
                      jnp.asarray(vis[order]))


def _check_sorted_merge_equals_argsort(L, M, seed):
    """merge_insert (sort-block + two-run merge) must equal the historical
    full-argsort implementation bit-for-bit — queue arrays, best_rank."""
    r = np.random.default_rng(seed)
    q = _random_queue(r, L)
    nd = jnp.asarray(r.normal(size=M).astype(np.float32))
    # id range overlapping the queue's so in-queue dups get exercised
    ni = jnp.asarray(r.integers(-1, 40, size=M).astype(np.int32))
    out, br, _ = qmod.merge_insert(q, nd, ni)
    exp, br_exp = _seed_merge_insert(q, nd, ni)
    assert np.array_equal(np.asarray(out.dists), np.asarray(exp.dists))
    assert np.array_equal(np.asarray(out.ids), np.asarray(exp.ids))
    assert np.array_equal(np.asarray(out.visited), np.asarray(exp.visited))
    assert int(br) == int(br_exp)
    # queue stays sorted ascending — the invariant pick_top_w exploits
    od = np.asarray(out.dists)
    assert np.all(od[:-1] <= od[1:])


def _check_merge_insert_beam_matches(L, W, seed):
    """The beam merge's queue equals merge_insert's for the same flat
    block, and rank[0] of a W=1 beam equals merge_insert's best_rank."""
    r = np.random.default_rng(seed)
    q = _random_queue(r, L)
    M = int(r.integers(1, 6)) * W
    nd = jnp.asarray(r.normal(size=M).astype(np.float32))
    ni = jnp.asarray(r.integers(-1, 40, size=M).astype(np.int32))
    out, br, _ = qmod.merge_insert(q, nd, ni)
    outw, ranks = qmod.merge_insert_beam(q, nd, ni, W)
    assert np.array_equal(np.asarray(out.dists), np.asarray(outw.dists))
    assert np.array_equal(np.asarray(out.ids), np.asarray(outw.ids))
    if W == 1:
        assert int(ranks[0]) == int(br)
    # every per-expansion rank is sane and >= the global best rank
    assert np.all((np.asarray(ranks) >= int(br)) & (np.asarray(ranks) <= L))


def _check_dedupe_ids(M, seed):
    """dedupe_ids keeps exactly the FIRST occurrence of every valid id."""
    r = np.random.default_rng(seed)
    ids = r.integers(-1, 8, size=M).astype(np.int32)
    out = np.asarray(qmod.dedupe_ids(jnp.asarray(ids)))
    seen = set()
    for j in range(M):
        if ids[j] >= 0 and ids[j] not in seen:
            assert out[j] == ids[j]
            seen.add(ids[j])
        else:
            assert out[j] == -1


def _check_pick_top_w(L, w, seed):
    """pick_top_w returns the first w unvisited finite slots in queue
    order, and pick_unvisited (w=1) matches the seed's masked argmin."""
    r = np.random.default_rng(seed)
    q = _random_queue(r, L)
    idxs, has = qmod.pick_top_w(q, w)
    dists = np.asarray(q.dists)
    vis = np.asarray(q.visited)
    expected = [i for i in range(L)
                if not vis[i] and np.isfinite(dists[i])][:w]
    assert int(np.asarray(has).sum()) == len(expected)
    assert np.asarray(idxs)[:len(expected)].tolist() == expected
    # seed equivalence at w=1
    idx1, has1 = qmod.pick_unvisited(q)
    sidx, shas = _seed_pick(q)
    assert bool(has1) == bool(shas)
    if bool(shas):
        assert int(idx1) == int(sidx)


# ---- seeded sweeps (always run) ----
def test_sorted_merge_equals_argsort_oracle_seeded():
    r = np.random.default_rng(0)
    for _ in range(20):
        _check_sorted_merge_equals_argsort(int(r.integers(2, 24)),
                                           int(r.integers(1, 16)),
                                           int(r.integers(0, 2 ** 30)))


def test_merge_insert_beam_matches_merge_insert_seeded():
    r = np.random.default_rng(1)
    for _ in range(12):
        _check_merge_insert_beam_matches(int(r.integers(2, 24)),
                                         int(r.integers(1, 5)),
                                         int(r.integers(0, 2 ** 30)))


def test_dedupe_ids_seeded():
    r = np.random.default_rng(2)
    for _ in range(20):
        _check_dedupe_ids(int(r.integers(1, 21)), int(r.integers(0, 2 ** 30)))


def test_pick_top_w_seeded():
    r = np.random.default_rng(3)
    for _ in range(20):
        _check_pick_top_w(int(r.integers(2, 24)), int(r.integers(1, 7)),
                          int(r.integers(0, 2 ** 30)))


# ---- hypothesis drivers (CI) ----
if HAVE_HYPOTHESIS:
    @given(st.integers(2, 24), st.integers(1, 16), st.integers(0, 2 ** 30))
    def test_sorted_merge_equals_argsort_oracle(L, M, seed):
        _check_sorted_merge_equals_argsort(L, M, seed)

    @given(st.integers(2, 24), st.integers(1, 4), st.integers(0, 2 ** 30))
    def test_merge_insert_beam_matches_merge_insert(L, W, seed):
        _check_merge_insert_beam_matches(L, W, seed)

    @given(st.integers(1, 20), st.integers(0, 2 ** 30))
    def test_dedupe_ids_property(M, seed):
        _check_dedupe_ids(M, seed)

    @given(st.integers(2, 24), st.integers(1, 6), st.integers(0, 2 ** 30))
    def test_pick_top_w_first_unvisited(L, w, seed):
        _check_pick_top_w(L, w, seed)
