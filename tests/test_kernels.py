"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _arr(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,b,d", [
    (1, 7, 16),          # degenerate 1-to-B (the paper's base op)
    (16, 128, 128),      # aligned
    (37, 201, 100),      # fully unaligned (padding path)
    (8, 64, 513),        # d > lane multiple
])
def test_batch_dist(metric, q, b, d):
    qv, xv = _arr(q, d), _arr(b, d)
    out = ops.batch_dist(qv, xv, metric=metric, tq=16, tb=32)
    exp = ref.batch_dist_ref(qv, xv, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_batch_dist_bf16(metric):
    qv = _arr(16, 128).astype(jnp.bfloat16)
    xv = _arr(32, 128).astype(jnp.bfloat16)
    out = ops.batch_dist(qv, xv, metric=metric)
    exp = ref.batch_dist_ref(qv, xv, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-1)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,m,n,d", [
    (4, 8, 100, 32),
    (9, 33, 257, 96),    # unaligned everything
])
def test_gather_dist(metric, q, m, n, d):
    qv, db = _arr(q, d), _arr(n, d)
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, m)).astype(np.int32))
    out = ops.gather_dist(qv, db, ids, metric=metric)
    exp = ref.gather_dist_ref(qv, db, ids, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-4)


def test_gather_dist_all_invalid():
    qv, db = _arr(2, 32), _arr(50, 32)
    ids = jnp.full((2, 5), -1, jnp.int32)
    out = np.asarray(ops.gather_dist(qv, db, ids))
    assert np.all(np.isinf(out))


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,m,n,d", [
    (4, 8, 100, 32),
    (9, 33, 257, 96),    # unaligned everything
])
def test_sq_gather_dist(metric, q, m, n, d):
    qv = _arr(q, d)
    codes = jnp.asarray(RNG.integers(0, 256, size=(n, d)).astype(np.uint8))
    scale = jnp.asarray((RNG.random(d) * 0.1 + 1e-3).astype(np.float32))
    zero = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, m)).astype(np.int32))
    out = ops.sq_gather_dist(qv, codes, scale, zero, ids, metric=metric)
    exp = ref.sq_gather_dist_ref(qv, codes, scale.reshape(1, -1),
                                 zero.reshape(1, -1), ids, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("q,b,n,m", [(2, 9, 64, 4), (5, 17, 200, 16)])
def test_pq_adc(q, b, n, m):
    lut = _arr(q, m, 256)
    codes = jnp.asarray(RNG.integers(0, 256, size=(n, m)).astype(np.uint8))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.pq_adc(lut, codes, ids)
    exp = ref.pq_adc_ref(lut, codes, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,c,w,n,d,L", [
    (3, 8, 1, 60, 32, 8),     # W=1 degenerate beam
    (5, 24, 4, 150, 96, 16),  # beam wider than the top-L block
    (2, 6, 3, 40, 100, 16),   # L > C: block shorter than the queue
])
def test_fused_expand(metric, q, c, w, n, d, L):
    qv, db = _arr(q, d), _arr(n, d)
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, c)).astype(np.int32))
    out = ops.fused_expand(qv, db, ids, metric=metric, L=L, n_beam=w)
    exp = ref.fused_expand_ref(qv, db, ids, metric, L, w)
    for a, b in zip(out, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-4)


def test_fused_expand_sorted_and_masked():
    """Output block is ascending with -1 ids beyond the finite prefix."""
    qv, db = _arr(4, 32), _arr(50, 32)
    ids = jnp.asarray(RNG.integers(-1, 50, size=(4, 12)).astype(np.int32))
    sd, si, bests, ties = ops.fused_expand(qv, db, ids, metric="l2",
                                           L=8, n_beam=2)
    sd, si = np.asarray(sd), np.asarray(si)
    assert np.all(sd[:, :-1] <= sd[:, 1:])
    assert np.all((si >= 0) == np.isfinite(sd))
    assert np.asarray(bests).shape == (4, 2)
    # expansion 0 has no earlier expansion; random f32 dists don't tie
    assert np.all(np.asarray(ties)[:, 0] == 0)
    assert np.all(np.asarray(ties) >= 0)


def test_fused_expand_pq():
    q, b, n, m = 4, 12, 80, 8
    lut = _arr(q, m, 256)
    codes = jnp.asarray(RNG.integers(0, 256, size=(n, m)).astype(np.uint8))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.fused_expand_pq(lut, codes, ids, L=8, n_beam=3)
    exp = ref.fused_expand_pq_ref(lut, codes, ids, 8, 3)
    for a, b_ in zip(out, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_fused_expand_pq4():
    q, b, n, m = 4, 12, 80, 8
    lut = _arr(q, m, 16)
    packed = jnp.asarray(RNG.integers(0, 256, size=(n, m // 2)).astype(np.uint8))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.fused_expand_pq4(lut, packed, ids, L=8, n_beam=2)
    exp = ref.fused_expand_pq4_ref(lut, packed, ids, 8, 2)
    for a, b_ in zip(out, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_fused_expand_sq():
    q, b, n, d = 3, 10, 60, 48
    qv = _arr(q, d)
    codes = jnp.asarray(RNG.integers(0, 256, size=(n, d)).astype(np.uint8))
    scale = jnp.asarray(np.abs(RNG.normal(size=d)).astype(np.float32) + .01)
    zero = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.fused_expand_sq(qv, codes, scale, zero, ids, metric="l2",
                              L=8, n_beam=2)
    exp = ref.fused_expand_sq_ref(qv, codes, scale.reshape(1, -1),
                                  zero.reshape(1, -1), ids, "l2", 8, 2)
    for a, b_ in zip(out, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-5, atol=3e-3)


def test_batch_dist_l2_nonnegative():
    qv = _arr(8, 64)
    out = np.asarray(ops.batch_dist(qv, qv, metric="l2"))
    assert np.all(out >= 0)
    assert np.allclose(np.diag(out), 0.0, atol=1e-3)


# --------------------------------------------------------------------------
# 1-bit Hamming kernels (DESIGN.md §14). Distances are small-integer
# popcount sums represented exactly in f32, so parity with the ref is
# EXACT equality — any allclose tolerance here would hide a bit-twiddling
# bug in the SWAR popcount ladder.
# --------------------------------------------------------------------------
def _bin_codes(n, d):
    from repro.core import quantize as qz
    bits = jnp.asarray(RNG.integers(0, 2, size=(n, d)).astype(np.uint32))
    return qz.pack_signs(bits)


@pytest.mark.parametrize("q,b,n,d", [
    (2, 9, 64, 32),      # single packed word
    (5, 17, 200, 100),   # non-multiple-of-32 tail (4 words, 28 pad bits)
    (4, 33, 150, 128),   # aligned multi-word
])
def test_bin_dist(q, b, n, d):
    qcodes = _bin_codes(q, d)
    codes = _bin_codes(n, d)
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = np.asarray(ops.bin_dist(qcodes, codes, ids))
    exp = np.asarray(ref.bin_dist_ref(qcodes, codes, ids))
    np.testing.assert_array_equal(out, exp)
    fin = out[np.isfinite(out)]
    assert np.array_equal(fin, np.round(fin))   # integral Hamming counts


def test_bin_dist_all_invalid():
    qcodes, codes = _bin_codes(2, 64), _bin_codes(50, 64)
    ids = jnp.full((2, 5), -1, jnp.int32)
    assert np.all(np.isinf(np.asarray(ops.bin_dist(qcodes, codes, ids))))


@pytest.mark.parametrize("q,c,w,n,d,L", [
    (3, 8, 1, 60, 32, 8),     # W=1 degenerate beam
    (5, 24, 4, 150, 100, 16), # beam wider than top-L, padded tail dim
    (2, 6, 3, 40, 128, 16),   # L > C: block shorter than the queue
])
def test_fused_expand_bin(q, c, w, n, d, L):
    qcodes = _bin_codes(q, d)
    codes = _bin_codes(n, d)
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, c)).astype(np.int32))
    out = ops.fused_expand_bin(qcodes, codes, ids, L=L, n_beam=w)
    exp = ref.fused_expand_bin_ref(qcodes, codes, ids, L, w)
    for a, b_ in zip(out, exp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_bin_ivf_scan():
    from repro.core import quantize as qz
    nlist, maxlen, d, q, L = 6, 32, 96, 4, 8
    list_codes = jnp.stack([_bin_codes(maxlen, d) for _ in range(nlist)])
    ids = RNG.permutation(nlist * maxlen)[: nlist * maxlen].reshape(
        nlist, maxlen).astype(np.int32)
    ids[:, 27:] = -1                                     # ragged tails
    list_ids = jnp.asarray(ids)
    qcodes = _bin_codes(q, d)
    probes = jnp.asarray(RNG.integers(0, nlist, size=(q, 3)).astype(np.int32))
    dk, ik = ops.bin_ivf_scan(qcodes, list_codes, list_ids, probes, L=L)
    de, ie = ref.bin_ivf_scan_ref(qcodes, list_codes, list_ids, probes, L)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(de))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ie))
