"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _arr(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,b,d", [
    (1, 7, 16),          # degenerate 1-to-B (the paper's base op)
    (16, 128, 128),      # aligned
    (37, 201, 100),      # fully unaligned (padding path)
    (8, 64, 513),        # d > lane multiple
])
def test_batch_dist(metric, q, b, d):
    qv, xv = _arr(q, d), _arr(b, d)
    out = ops.batch_dist(qv, xv, metric=metric, tq=16, tb=32)
    exp = ref.batch_dist_ref(qv, xv, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_batch_dist_bf16(metric):
    qv = _arr(16, 128).astype(jnp.bfloat16)
    xv = _arr(32, 128).astype(jnp.bfloat16)
    out = ops.batch_dist(qv, xv, metric=metric)
    exp = ref.batch_dist_ref(qv, xv, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-1)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,m,n,d", [
    (4, 8, 100, 32),
    (9, 33, 257, 96),    # unaligned everything
])
def test_gather_dist(metric, q, m, n, d):
    qv, db = _arr(q, d), _arr(n, d)
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, m)).astype(np.int32))
    out = ops.gather_dist(qv, db, ids, metric=metric)
    exp = ref.gather_dist_ref(qv, db, ids, metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-4)


def test_gather_dist_all_invalid():
    qv, db = _arr(2, 32), _arr(50, 32)
    ids = jnp.full((2, 5), -1, jnp.int32)
    out = np.asarray(ops.gather_dist(qv, db, ids))
    assert np.all(np.isinf(out))


@pytest.mark.parametrize("q,b,n,m", [(2, 9, 64, 4), (5, 17, 200, 16)])
def test_pq_adc(q, b, n, m):
    lut = _arr(q, m, 256)
    codes = jnp.asarray(RNG.integers(0, 256, size=(n, m)).astype(np.uint8))
    ids = jnp.asarray(RNG.integers(-1, n, size=(q, b)).astype(np.int32))
    out = ops.pq_adc(lut, codes, ids)
    exp = ref.pq_adc_ref(lut, codes, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_batch_dist_l2_nonnegative():
    qv = _arr(8, 64)
    out = np.asarray(ops.batch_dist(qv, qv, metric="l2"))
    assert np.all(out >= 0)
    assert np.allclose(np.diag(out), 0.0, atol=1e-3)
