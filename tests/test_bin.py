"""1-bit binary quantization (DESIGN.md §14): pack/unpack inverse and
Hamming==sign-disagreement properties (hypothesis-driven in CI, seeded
sweeps always), kernel-vs-ref exact parity on graph and IVF paths,
save/load sidecars, sharded parity, rescore_factor monotonicity, the
quant-kind registry, and the 50k acceptance recall floor."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.core import types as types_mod
from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k

RNG = np.random.default_rng(31)

# the non-multiple-of-32 cases exercise tail padding: both sides of the
# XOR leave the pad bits zero, so they never contribute to the Hamming sum
DIMS = (32, 64, 100, 128)

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("bin", max_examples=25, deadline=None)
    settings.load_profile("bin")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _graph_cfg(dim, metric, **skw):
    s = dict(L=64, k=10, early_term=False)
    s.update(skw)
    return IndexConfig(
        dim=dim, metric=metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                          reorder="none"),
        search=SearchConfig(**s),
        quant=QuantConfig(kind="bin"))


def _ivf_cfg(dim, metric, **skw):
    s = dict(L=64, k=10, nprobe=8)
    s.update(skw)
    return IndexConfig(
        dim=dim, metric=metric, index_type="ivf",
        ivf=IVFConfig(nlist=32, kmeans_iters=5, list_pad=8),
        quant=QuantConfig(kind="bin"),
        search=SearchConfig(**s))


# ------------------------------------------------------------- properties
def _check_pack_roundtrip(d, n, seed):
    """unpack_signs(pack_signs(bits), d) == bits, with the packed tail
    bits of the last word provably zero."""
    r = np.random.default_rng(seed)
    bits = r.integers(0, 2, size=(n, d)).astype(np.uint32)
    packed = qz.pack_signs(jnp.asarray(bits))
    nw = -(-d // 32)
    assert packed.shape == (n, nw) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(qz.unpack_signs(packed, d)), bits)
    if d % 32:
        tail = np.asarray(packed)[:, -1] >> np.uint32(d % 32)
        assert np.all(tail == 0)


def _check_hamming_is_sign_disagreement(d, n, seed):
    """Packed XOR+popcount == popcount of elementwise sign disagreement
    computed on the UNPACKED bits (the bit-level oracle)."""
    r = np.random.default_rng(seed)
    a = r.integers(0, 2, size=(1, d)).astype(np.uint32)
    b = r.integers(0, 2, size=(n, d)).astype(np.uint32)
    from repro.kernels.ref import bin_dist_ref
    ids = jnp.arange(n, dtype=jnp.int32)[None]
    got = np.asarray(bin_dist_ref(qz.pack_signs(jnp.asarray(a)),
                                  qz.pack_signs(jnp.asarray(b)), ids))[0]
    np.testing.assert_array_equal(got, (a != b).sum(axis=1))


def test_pack_roundtrip_seeded():
    r = np.random.default_rng(0)
    for d in DIMS:
        for _ in range(5):
            _check_pack_roundtrip(d, int(r.integers(1, 40)),
                                  int(r.integers(0, 2 ** 30)))


def test_hamming_is_sign_disagreement_seeded():
    r = np.random.default_rng(1)
    for d in DIMS:
        for _ in range(5):
            _check_hamming_is_sign_disagreement(d, int(r.integers(1, 40)),
                                                int(r.integers(0, 2 ** 30)))


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(DIMS), st.integers(1, 40),
           st.integers(0, 2 ** 30))
    def test_pack_roundtrip_property(d, n, seed):
        _check_pack_roundtrip(d, n, seed)

    @given(st.sampled_from(DIMS), st.integers(1, 40),
           st.integers(0, 2 ** 30))
    def test_hamming_is_sign_disagreement_property(d, n, seed):
        _check_hamming_is_sign_disagreement(d, n, seed)


# ---------------------------------------------------------------- encoding
def test_rotation_is_orthonormal():
    st_ = qz.bin_train(jnp.asarray(RNG.normal(size=(50, 100)),
                                   jnp.float32), QuantConfig(kind="bin"))
    r = np.asarray(st_.rot)
    np.testing.assert_allclose(r @ r.T, np.eye(100), atol=1e-4)
    assert st_.n_words == 4   # ceil(100/32)


def test_encode_deterministic_in_seed():
    x = jnp.asarray(RNG.normal(size=(64, 96)), jnp.float32)
    a = qz.bin_encode(qz.bin_train(x, QuantConfig(kind="bin", seed=3)), x)
    b = qz.bin_encode(qz.bin_train(x, QuantConfig(kind="bin", seed=3)), x)
    c = qz.bin_encode(qz.bin_train(x, QuantConfig(kind="bin", seed=4)), x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ----------------------------------------------------------- end-to-end paths
def test_graph_bin_kernel_impl_matches_ref(deep_ds):
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric)
    idx = KBest(cfg).add(deep_ds.base)
    assert idx.bin_codes.dtype == jnp.uint32
    s_k = dataclasses.replace(cfg.search, dist_impl="kernel")
    d_r, i_r = idx.search(deep_ds.queries[:8], k=10)
    d_k, i_k = idx.search(deep_ds.queries[:8], k=10, search_cfg=s_k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-6)


def test_ivf_bin_kernel_impl_matches_ref(deep_ds):
    cfg = _ivf_cfg(deep_ds.base.shape[1], deep_ds.metric)
    idx = KBest(cfg).add(deep_ds.base)
    assert idx.ivf.bin is not None and idx.ivf.pq is None
    assert idx.ivf.list_codes.dtype == jnp.uint32
    s_k = dataclasses.replace(cfg.search, dist_impl="kernel")
    d_r, i_r = idx.search(deep_ds.queries[:8], k=10)
    d_k, i_k = idx.search(deep_ds.queries[:8], k=10, search_cfg=s_k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-6)


def test_graph_bin_recall_with_rescore(deep_ds):
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric,
                     L=96, rescore_factor=8)
    idx = KBest(cfg).add(deep_ds.base)
    _, i = idx.search(deep_ds.queries, k=10)
    assert recall_at_k(np.asarray(i), deep_ds.gt_ids, 10) >= 0.75


def test_rescore_factor_monotone_recall(deep_ds):
    """With rescore_factor*k <= L the Hamming traversal is identical
    across factors and the exact rescore sees a superset of candidates:
    recall@10 must be non-decreasing in rescore_factor."""
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric, L=64)
    idx = KBest(cfg).add(deep_ds.base)
    recs = []
    for rf in (1, 2, 4, 6):
        s = dataclasses.replace(cfg.search, rescore_factor=rf)
        _, ids = idx.search(deep_ds.queries, search_cfg=s)
        recs.append(recall_at_k(np.asarray(ids), deep_ds.gt_ids, 10))
    assert all(b >= a for a, b in zip(recs, recs[1:])), recs
    assert recs[-1] > recs[0], recs   # rescore must actually help


def test_bin_code_bytes_32x_under_f32(deep_ds):
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric)
    idx = KBest(cfg).add(deep_ds.base)
    d = deep_ds.base.shape[1]
    assert qz.code_bytes_per_vector(idx) * 32 == 4 * ((d + 31) // 32 * 32)


# save/load round-trips live in tests/test_saveload.py, parameterized
# over the whole quant registry (bin included, graph + IVF).


# ------------------------------------------------------------------ sharded
def test_sharded_bin_one_shard_parity(deep_ds):
    from repro.core.sharded import ShardedKBest
    cfg = _graph_cfg(deep_ds.base.shape[1], deep_ds.metric,
                     L=48, rescore_factor=4)
    a = KBest(cfg).add(deep_ds.base)
    b = ShardedKBest(cfg, n_shards=1).add(deep_ds.base)
    da, ia = a.search(deep_ds.queries)
    db_, ib = b.search(deep_ds.queries)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db_))


# ----------------------------------------------------------------- registry
def test_quant_variant_registry_covers_quant_kinds():
    """quantize.quant_variants (what tune.py and benchmarks/ablation.py
    enumerate) must stay in sync with types.QUANT_KINDS: every accepted
    kind appears in at least one variant, and every variant's kind is
    accepted."""
    variants = qz.quant_variants()
    kinds = {v["kind"] for v in variants.values()}
    assert kinds == set(types_mod.QUANT_KINDS)
    for v in variants.values():
        QuantConfig(**v)                            # must not raise


# ------------------------------------------------------------------- recall
def test_bin_recall_50k_deep():
    """Acceptance: the deep_like IVF-bin preset reaches recall@10 >= 0.90
    on the 50k set — 12 code bytes/vector (96 sign bits), 8x under a
    per-dimension u8 code, with the deep exact rescore doing the recovery
    (DESIGN.md §14). Graph-bin at this scale needs a far wider queue for
    the same floor (see BENCH_bin.json), so the tier-1 floor rides the
    cheap-to-build IVF preset, as test_pq4 does."""
    from repro.configs import kbest as kcfg
    ds = make_dataset("deep_like", n=50_000, n_queries=50, k=10)
    cfg = kcfg.ivf_bin_index_config("deep_like")
    idx = KBest(cfg).add(ds.base)
    _, ids = idx.search(ds.queries, k=10)
    rec = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert rec >= 0.90, rec
    assert qz.code_bytes_per_vector(idx) * 8 <= ds.base.shape[1]
