def search(cfg):
    # reads L directly and max_hops through the hops_bound property;
    # phantom_knob is read nowhere -> dead knob
    return cfg.L + cfg.hops_bound
