"""Seeded violation: phantom_knob is declared but never read anywhere."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    L: int = 64
    max_hops: int = 0
    phantom_knob: int = 0

    @property
    def hops_bound(self) -> int:
        # property bridge: keeps max_hops live because hops_bound is
        # read externally (search.py below)
        return self.max_hops if self.max_hops > 0 else self.L
