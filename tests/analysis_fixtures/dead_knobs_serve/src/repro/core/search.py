from repro.core.types import SearchConfig


def search(cfg: SearchConfig):
    return cfg.L
