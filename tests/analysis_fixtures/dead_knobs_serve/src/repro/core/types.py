"""Clean core config classes: this fixture's seeded violations live in
the serve/ tree only."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    L: int = 64
