"""Seeded violation: phantom_deadline_knob is set by callers but never
consulted anywhere — the admission-forgot-to-read-it bug class."""
import dataclasses


@dataclasses.dataclass
class Request:
    queries: object = None
    deadline_ms: float = 0.0
    phantom_deadline_knob: float = 0.0


def serve_loop(requests):
    out = []
    for r in requests:
        if r.deadline_ms > 0:            # deadline_ms: live
            out.append(r.queries)        # queries: live
    return out
