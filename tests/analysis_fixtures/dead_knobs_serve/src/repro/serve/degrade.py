"""Seeded violation: phantom_watermark_ms is declared but no method (or
anyone else) reads it — a watermark that can never trigger."""
import dataclasses


@dataclasses.dataclass
class DegradePolicy:
    ladder: tuple = ()
    high_ms: float = 50.0
    phantom_watermark_ms: float = 0.0

    def observe(self, queue_delay_ms):
        # ladder + high_ms: live via self-reads (the relaxed serve rule)
        if queue_delay_ms > self.high_ms:
            return len(self.ladder)
        return 0
