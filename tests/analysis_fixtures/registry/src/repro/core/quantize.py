def quant_variants(pq_m=16):
    # missing a variant for kind "zq"
    return {
        "full": dict(kind="none"),
        "pq8": dict(kind="pq", pq_m=pq_m),
    }


IVF_QUANT_KINDS = ("pq",)
