"""Seeded violation: kind "zq" is registered here but wired nowhere —
quant_variants misses it, no sidecar tokens are registered for it, and
no preset constructs it."""

QUANT_KINDS = ("none", "pq", "zq")
