# Seeded violation: a hand-enumerated quant-kind list instead of
# deriving from quantize.quant_variants.
QUANTS = ("none", "pq", "zq")
