"""Seeded violations: Python-level control flow on traced values inside
a Pallas kernel body — an `if`, an `assert`, and a `float()` cast."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    v = x_ref[0, 0]
    if v > 0:                    # traced-value branch
        o_ref[0, 0] = v
    assert v >= 0                # traced-value assert
    o_ref[0, 1] = float(v)       # concretizing cast


def bad_branch(x):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
