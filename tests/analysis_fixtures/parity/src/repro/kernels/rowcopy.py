"""Seeded violation: a Pallas kernel with no ref.py oracle, no ops.py
dispatch entry, and no parity test."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def rowcopy(x):
    return pl.pallas_call(
        _kernel,
        grid=(x.shape[0],),
        in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
