"""Seeded violation for the cost check: a Pallas kernel with NO
KERNEL_COSTS entry and a grid dimension (`zz`) the workload bindings
cannot resolve — the cost model must refuse to silently skip it."""


def _mystery_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def mystery_scan(x, zz):
    import jax.experimental.pallas as pl
    return pl.pallas_call(
        _mystery_kernel,
        grid=(zz, 4),
        in_specs=[pl.BlockSpec((1, 4), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 4), lambda i, j: (i, j)),
        out_shape=None,
    )(x)
