"""Seeded violation: a (4096, 4096) f32 block is 64 MiB — double-buffered
in and out blocks put ~256 MiB in VMEM against a 16 MiB budget."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def huge_tile(x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((4096, 4096), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((4096, 4096), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
