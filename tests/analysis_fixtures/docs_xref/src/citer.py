"""Cites a section that does not exist in this tree's DESIGN.md
(see DESIGN.md §9) — the dangling-citation rule fires here."""

SECTION = 9
