import os
import sys

# tests must see ONE cpu device (the dry-run sets 512 itself; never here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def deep_ds():
    from repro.data.vectors import make_dataset
    return make_dataset("deep_like", n=2000, n_queries=40, k=10)


@pytest.fixture(scope="session")
def bigann_ds():
    from repro.data.vectors import make_dataset
    return make_dataset("bigann_like", n=2000, n_queries=40, k=10)


def _build(ds, **kw):
    from repro.core.index import KBest
    from repro.core.types import BuildConfig, IndexConfig, SearchConfig
    build = dict(M=24, knn_k=32, builder="brute", refine_iters=1,
                 refine_cands=64)
    build.update(kw)
    cfg = IndexConfig(dim=ds.base.shape[1], metric=ds.metric,
                      build=BuildConfig(**build),
                      search=SearchConfig(L=64, k=10, early_term=False))
    return KBest(cfg).add(ds.base)


@pytest.fixture(scope="session")
def deep_index(deep_ds):
    return _build(deep_ds)


@pytest.fixture(scope="session")
def bigann_index(bigann_ds):
    return _build(bigann_ds)
