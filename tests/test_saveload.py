"""Save/load round-trips parameterized over THE quant registry
(quantize.quant_variants x graph, quantize.IVF_QUANT_KINDS x IVF) —
replaces the per-kind hand-written round-trip tests, so a kind added to
the registry is round-trip-tested automatically (kbest-lint enforces the
registry side). Also pins the forward-compat warning: _config_from_dict
must name the keys it drops instead of silently losing knobs."""
import dataclasses

import numpy as np
import pytest

from repro.core import quantize as qz
from repro.core.index import KBest, _config_from_dict, _config_to_dict
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset

PQ_M = 16
VARIANTS = qz.quant_variants(pq_m=PQ_M)

# Arrays each kind must persist (graph / IVF side) — asserted against the
# saved npz so a save() regression shows up as a missing array, not just
# as drifted search results.
_GRAPH_ARRAYS = {"pq": ("pq_codebooks", "pq_codes"),
                 "pq4": ("pq_codebooks", "pq_codes"),
                 "sq": ("sq_scale", "sq_zero", "sq_codes"),
                 "bin": ("bin_rot", "bin_codes")}
_IVF_ARRAYS = {"pq": ("ivf_codebooks",), "pq4": ("ivf_codebooks",),
               "bin": ("ivf_bin_rot",)}


@pytest.fixture(scope="module")
def ds():
    return make_dataset("bigann_like", n=500, n_queries=10, k=10)


def _roundtrip(idx, ds, tmp_path, name):
    d1, i1 = idx.search(ds.queries, k=10)
    path = tmp_path / name
    idx.save(str(path))
    assert path.with_name(path.name + ".json").exists()   # per-name sidecar
    idx2 = KBest.load(str(path))
    assert idx2.config == idx.config
    d2, i2 = idx2.search(ds.queries, k=10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    return path


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_graph_roundtrip(tmp_path, ds, variant):
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=16, knn_k=24, builder="brute", refine_iters=0),
        quant=QuantConfig(kmeans_iters=4, **VARIANTS[variant]),
        search=SearchConfig(L=48, k=10, early_term=False))
    idx = KBest(cfg).add(ds.base)
    path = _roundtrip(idx, ds, tmp_path, f"graph_{variant}.npz".replace(
        "+", "_"))
    kind = VARIANTS[variant]["kind"]
    with np.load(path) as z:
        for key in _GRAPH_ARRAYS.get(kind, ()):
            assert key in z, f"save() lost '{key}' for kind '{kind}'"


@pytest.mark.parametrize("kind", qz.IVF_QUANT_KINDS)
def test_ivf_roundtrip(tmp_path, ds, kind):
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(nlist=16, kmeans_iters=4, list_pad=8),
        quant=QuantConfig(kind=kind, pq_m=PQ_M, kmeans_iters=4),
        search=SearchConfig(L=48, k=10, nprobe=8, rescore_factor=4))
    idx = KBest(cfg).add(ds.base)
    path = _roundtrip(idx, ds, tmp_path, f"ivf_{kind}.npz")
    with np.load(path) as z:
        for key in _IVF_ARRAYS[kind]:
            assert key in z, f"save() lost '{key}' for IVF kind '{kind}'"
        # the bin IVF codec must not drag a vestigial PQ stage along
        if kind == "bin":
            assert "ivf_codebooks" not in z


def test_config_from_dict_warns_on_dropped_keys():
    d = _config_to_dict(IndexConfig(dim=32, metric="l2"))
    d["search"]["knob_from_the_future"] = 7
    d["quant"]["other_new_knob"] = "x"
    with pytest.warns(UserWarning) as rec:
        cfg = _config_from_dict(d)
    msgs = "\n".join(str(w.message) for w in rec)
    assert "knob_from_the_future" in msgs and "SearchConfig" in msgs
    assert "other_new_knob" in msgs and "QuantConfig" in msgs
    assert cfg.search.L == IndexConfig(dim=32, metric="l2").search.L


def test_config_from_dict_quiet_on_known_keys():
    import warnings as w
    d = _config_to_dict(IndexConfig(dim=32, metric="l2"))
    with w.catch_warnings():
        w.simplefilter("error")
        _config_from_dict(d)
