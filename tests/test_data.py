"""Data pipelines: determinism, host sharding, sampler shape contracts."""
import numpy as np

from repro.data import pipeline as pl


def test_lm_batches_deterministic_and_host_sharded():
    a = next(pl.lm_batches(100, 8, 16, seed=1, host_id=0, n_hosts=2))
    b = next(pl.lm_batches(100, 8, 16, seed=1, host_id=0, n_hosts=2))
    c = next(pl.lm_batches(100, 8, 16, seed=1, host_id=1, n_hosts=2))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 17)


def test_prefetcher_order_preserved():
    it = pl.Prefetcher(iter(range(20)))
    assert list(it) == list(range(20))


def test_ctr_batches_learnable_signal():
    it = pl.ctr_batches(6, 1000, 512, seed=0)
    b = next(it)
    assert b["sparse_ids"].shape == (512, 6)
    assert 0.2 < b["label"].mean() < 0.8   # non-degenerate labels


def test_seq_batches_shapes():
    bst = next(pl.seq_batches("bst", 1000, 16, 12, seed=0))
    assert bst["hist"].shape == (16, 12) and bst["target"].shape == (16,)
    b4 = next(pl.seq_batches("bert4rec", 1000, 16, 12, seed=0))
    assert b4["seq"].shape == (16, 12)
    masked = (b4["labels"] >= 0)
    assert 0.02 < masked.mean() < 0.4
    # masked positions are replaced in the input
    assert np.all(b4["seq"][masked] == 0)


def test_neighbor_sampler_contract():
    indptr, indices = pl.synthetic_graph(500, avg_degree=10, seed=0)
    assert indptr[-1] == len(indices)
    rng = np.random.default_rng(0)
    seeds = np.array([0, 5, 10])
    nb = pl.sample_neighbors(indptr, indices, seeds, 4, rng)
    assert nb.shape == (3, 4)
    # sampled neighbors are real neighbors (or self for isolated nodes)
    for i, s in enumerate(seeds):
        own = set(indices[indptr[s]:indptr[s + 1]].tolist()) | {s}
        assert set(nb[i].tolist()) <= own


def test_gnn_minibatch_fixed_shapes():
    it = pl.gnn_minibatches(n_nodes=300, d_feat=8, batch_nodes=4,
                            fanouts=(3, 2), triplet_cap=2)
    b1, b2 = next(it), next(it)
    for k in b1:
        assert b1[k].shape == b2[k].shape, k
    E = b1["edge_src"].shape[0]
    assert E == 4 * 3 + 4 * 3 * 2
    valid = b1["edge_src"] >= 0
    n_nodes = b1["feats"].shape[0]
    assert np.all(b1["edge_src"][valid] < n_nodes)
    # triplet indices point into the edge list
    tv = b1["trip_ji"] >= 0
    assert np.all(b1["trip_ji"][tv] < E)


def test_molecule_batches_graph_ids():
    b = next(pl.molecule_batches(n_atoms=5, n_edges=10, batch=3, d_feat=4))
    assert b["node_graph"].shape == (15,)
    assert set(b["node_graph"].tolist()) == {0, 1, 2}
    assert b["targets"].shape == (3,)


def test_vector_datasets_reproducible():
    from repro.data.vectors import make_dataset
    a = make_dataset("glove_like", n=500, n_queries=10, k=5, seed=3)
    b = make_dataset("glove_like", n=500, n_queries=10, k=5, seed=3)
    np.testing.assert_array_equal(a.base, b.base)
    np.testing.assert_array_equal(a.gt_ids, b.gt_ids)
