"""Batch-serving engine (repro.serve) + search-path correctness regressions.

Covers the serving tentpole (shape-bucketed compile cache, padded-lane
bit-identity, coalescing scheduler with true served-count accounting) and
the search-path bugfixes that shipped with and after it:
  1. duplicate entry seeds corrupting the visited bitmap (scatter-add carry)
  2. partial-batch recall denominators (served-count accounting)
  3. graph-quantized n_dist excluding exact re-rank distances (cross-family
     comparability with the IVF path)
  4. `search(q, k=K)` with K > SearchConfig.L asserting instead of widening
  5. n_dist excluding the entry-seed distances computed at traversal init
     (undercounted by n_entries across every graph family)
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search as search_mod
from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k
from repro.serve import Request, SearchEngine, bucket_for, serve_loop


@pytest.fixture(scope="module")
def tiny_ds():
    # small enough that L=256 >= n: the queue holds every discovered node,
    # so "queue" mode is exact and any bitmap corruption shows up as a diff
    return make_dataset("deep_like", n=200, n_queries=16, k=10)


@pytest.fixture(scope="module")
def tiny_index(tiny_ds):
    cfg = IndexConfig(
        dim=tiny_ds.base.shape[1], metric=tiny_ds.metric,
        build=BuildConfig(M=8, knn_k=16, builder="brute", refine_iters=0,
                          reorder="none"),
        search=SearchConfig(L=64, k=10, early_term=False))
    return KBest(cfg).add(tiny_ds.base)


# ---------------------------------------------------------------- tentpole
def test_compile_cache_one_trace_per_bucket(deep_ds, deep_index):
    eng = SearchEngine(deep_index, min_bucket=8, max_bucket=32)
    for q in (5, 6, 7):                       # three sizes, one bucket (8)
        eng.search(deep_ds.queries[:q])
    assert eng.n_traces == 1, "same bucket must compile exactly once"
    assert eng.cache_misses == 1 and eng.cache_hits == 2
    eng.search(deep_ds.queries[:12])          # bucket 16 -> one more trace
    assert eng.n_traces == 2
    eng.search(deep_ds.queries[:13])          # bucket 16 again -> cached
    assert eng.n_traces == 2
    # a different k is a different SearchConfig => its own cache entry
    eng.search(deep_ds.queries[:5], k=5)
    assert eng.n_traces == 3


def test_padded_results_bit_identical(deep_ds, deep_index):
    eng = SearchEngine(deep_index, min_bucket=16, max_bucket=32)
    for q in (3, 11, 16):
        d_pad, i_pad = eng.search(deep_ds.queries[:q])
        d_ref, i_ref = deep_index.search(deep_ds.queries[:q])
        assert d_pad.shape == (q, 10)
        np.testing.assert_array_equal(i_pad, np.asarray(i_ref))
        np.testing.assert_array_equal(d_pad, np.asarray(d_ref))


def test_search_padded_invalid_lanes_cost_nothing(deep_ds, deep_index):
    qp = np.zeros((8, deep_ds.queries.shape[1]), np.float32)
    qp[:3] = deep_ds.queries[:3]
    mask = np.zeros(8, bool)
    mask[:3] = True
    d, i, st = deep_index.search_padded(qp, mask, with_stats=True)
    assert np.all(np.isinf(np.asarray(d)[3:]))
    assert np.all(np.asarray(i)[3:] == -1)
    assert np.all(np.asarray(st.n_dist)[3:] == 0)
    assert np.all(np.asarray(st.n_hops)[3:] == 0)
    assert np.all(np.asarray(st.n_dist)[:3] > 0)


def test_search_padded_ivf_lanes_masked(tiny_ds):
    ivf = KBest(IndexConfig(
        dim=tiny_ds.base.shape[1], metric=tiny_ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4, list_pad=32),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4),
        search=SearchConfig(L=64, k=10, nprobe=4))).add(tiny_ds.base)
    qp = np.zeros((8, tiny_ds.queries.shape[1]), np.float32)
    qp[:5] = tiny_ds.queries[:5]
    mask = np.zeros(8, bool)
    mask[:5] = True
    d, i, st = ivf.search_padded(qp, mask, with_stats=True)
    assert np.all(np.isinf(np.asarray(d)[5:]))
    assert np.all(np.asarray(i)[5:] == -1)
    assert np.all(np.asarray(st.n_dist)[5:] == 0)
    d_ref, i_ref = ivf.search(tiny_ds.queries[:5])
    np.testing.assert_array_equal(np.asarray(i)[:5], np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(d)[:5], np.asarray(d_ref))


def test_warmup_precompiles(deep_ds, deep_index):
    eng = SearchEngine(deep_index, min_bucket=8, max_bucket=32)
    fresh = eng.warmup()                       # whole ladder: 8, 16, 32
    assert fresh == 3
    before = eng.n_traces
    for q in (2, 9, 17, 30):
        eng.search(deep_ds.queries[:q])
    assert eng.n_traces == before, "warmed buckets must never re-trace"


def test_oversized_batch_splits(deep_ds, deep_index):
    eng = SearchEngine(deep_index, min_bucket=8, max_bucket=16)
    d, i = eng.search(deep_ds.queries[:40])    # 16 + 16 + 8
    assert d.shape == (40, 10)
    d_ref, i_ref = deep_index.search(deep_ds.queries[:40])
    np.testing.assert_array_equal(i, np.asarray(i_ref))


def test_serve_loop_mixed_families_and_k(tiny_ds, tiny_index):
    ivf = KBest(IndexConfig(
        dim=tiny_ds.base.shape[1], metric=tiny_ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4, list_pad=32),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4),
        search=SearchConfig(L=64, k=10, nprobe=4))).add(tiny_ds.base)
    engines = {"graph": SearchEngine(tiny_index, max_bucket=16, name="graph"),
               "ivf": SearchEngine(ivf, max_bucket=16, name="ivf")}
    reqs = [
        Request(queries=tiny_ds.queries[:5], engine="graph", k=3,
                gt_ids=tiny_ds.gt_ids[:5]),
        Request(queries=tiny_ds.queries[5:12], engine="ivf", k=10,
                gt_ids=tiny_ds.gt_ids[5:12]),
        Request(queries=tiny_ds.queries[12:16], engine="graph", k=3,
                gt_ids=tiny_ds.gt_ids[12:16]),
    ]
    rep = serve_loop(engines, reqs)
    assert rep.n_served == 16
    assert [r.ids.shape for r in rep.results] == [(5, 3), (7, 10), (4, 3)]
    by_id = {r.request_id: r for r in rep.results}
    assert set(by_id) == {0, 1, 2}
    assert rep.recall_at_k is not None and rep.recall_at_k > 0.5


def test_serve_loop_coalesces_consecutive_compatible(tiny_ds, tiny_index):
    eng = SearchEngine(tiny_index, min_bucket=8, max_bucket=32)
    reqs = [Request(queries=tiny_ds.queries[s:s + 4]) for s in (0, 4, 8)]
    rep = serve_loop(eng, reqs)
    assert rep.n_dispatches == 1, "3x4 compatible rows pack into one bucket"
    assert rep.n_requests == 3 and rep.n_served == 12
    # sliced-back results match per-request direct searches
    for r, s in zip(rep.results, (0, 4, 8)):
        d_ref, i_ref = tiny_index.search(tiny_ds.queries[s:s + 4])
        np.testing.assert_array_equal(r.ids, np.asarray(i_ref))


def test_bucket_for():
    assert bucket_for(1) == 8               # min_bucket clamp
    assert bucket_for(8) == 8
    assert bucket_for(9) == 16
    assert bucket_for(100) == 128
    assert bucket_for(1000, max_bucket=256) == 256


# ------------------------------------------------- bugfix 1: bitmap seeds
def test_bitmap_set_tolerates_duplicates_and_resets():
    bm = jnp.zeros((2,), jnp.uint32)
    out = search_mod._bitmap_set(bm, jnp.array([5, 5, 5], jnp.int32))
    assert int(out[0]) == 1 << 5, "duplicate ids must set the bit ONCE"
    # setting an already-set bit again must not carry either
    out2 = search_mod._bitmap_set(out, jnp.array([5, 6], jnp.int32))
    assert int(out2[0]) == (1 << 5) | (1 << 6)
    # invalid ids are ignored
    out3 = search_mod._bitmap_set(out2, jnp.array([-1, -1], jnp.int32))
    assert int(out3[0]) == (1 << 5) | (1 << 6) and int(out3[1]) == 0


def test_bitmap_parity_with_colliding_entry_seeds(tiny_ds, tiny_index):
    # deliberately colliding seeds: the medoid duplicated plus adjacent
    # pairs — pre-fix, the scatter-add carry marks UNVISITED neighbors as
    # visited, silently dropping them from the candidate set
    e = tiny_index.entry
    n = tiny_index.db.shape[0]
    seeds = jnp.array([e, e, (e + 7) % n, (e + 7) % n, e], jnp.int32)
    dist_fn = tiny_index._get_dist_fn("full", "ref")
    out = {}
    for mode in ("queue", "bitmap"):
        cfg = SearchConfig(L=256, k=10, early_term=False, visited_mode=mode)
        d, ids, _ = search_mod.search(
            tiny_index.graph, jnp.asarray(tiny_ds.queries), seeds,
            dist_fn=dist_fn, cfg=cfg, n_total=n)
        out[mode] = np.asarray(ids)
    np.testing.assert_array_equal(out["bitmap"], out["queue"])


def test_entry_ids_distinct():
    idx = KBest.__new__(KBest)               # only _entry_ids is exercised
    for entry in (0, 3, 97):
        idx.entry = entry
        for n in (2, 3, 5, 8, 9, 100, 4001):
            for e in (1, 2, 8, 16):
                ids = np.asarray(idx._entry_ids(e, n))
                assert ids[0] == entry % n
                assert len(set(ids.tolist())) == len(ids), (n, e, ids)
                assert ids.min() >= 0 and ids.max() < n


# --------------------------------------- bugfix 2: partial-batch accounting
def test_partial_batch_true_served_count(tiny_ds, tiny_index):
    eng = SearchEngine(tiny_index, min_bucket=8, max_bucket=8)
    # 14 queries in batches of 8 => 8 + 6 (partial): the old denominator
    # ceil-batches * batch_size would claim 16 served
    reqs = [Request(queries=tiny_ds.queries[s:min(s + 8, 14)],
                    gt_ids=tiny_ds.gt_ids[s:min(s + 8, 14)])
            for s in range(0, 14, 8)]
    rep = serve_loop(eng, reqs, coalesce=False)
    assert rep.n_served == 14
    assert rep.engine_stats[eng.name].n_queries == 14
    assert len(range(0, 14, 8)) * 8 == 16     # the buggy denominator
    # recall over the true count must match a straight evaluation
    d, i = tiny_index.search(tiny_ds.queries[:14])
    direct = recall_at_k(np.asarray(i), tiny_ds.gt_ids[:14], 10)
    assert rep.recall_at_k == pytest.approx(direct, abs=1e-9)


# ------------------------------------ bugfix 3: n_dist includes the re-rank
def test_graph_quantized_ndist_counts_rerank(deep_ds):
    cfg = IndexConfig(
        dim=deep_ds.base.shape[1], metric=deep_ds.metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                          reorder="none"),
        search=SearchConfig(L=64, k=10, early_term=False),
        quant=QuantConfig(kind="sq", rerank=20))
    idx = KBest(cfg).add(deep_ds.base)
    q = deep_ds.queries[:8]
    _, _, st20 = idx.search(q, with_stats=True)
    # deepening the exact re-rank by 20 must add exactly 20 distances/query
    # (all candidates valid on this corpus) — pre-fix both reported the
    # same n_dist because the re-rank was invisible to the stats
    idx.config = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, rerank=40))
    _, _, st40 = idx.search(q, with_stats=True)
    np.testing.assert_array_equal(
        np.asarray(st40.n_dist) - np.asarray(st20.n_dist),
        np.full(8, 20, np.int32))


def test_ivf_and_graph_ndist_same_units(tiny_ds, tiny_index):
    # both families must count approx-pass evaluations + exact re-ranks;
    # IVF n_dist >= its re-rank depth and graph-SQ n_dist >= its re-rank
    ivf = KBest(IndexConfig(
        dim=tiny_ds.base.shape[1], metric=tiny_ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4, list_pad=32),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4, rerank=12),
        search=SearchConfig(L=64, k=10, nprobe=4))).add(tiny_ds.base)
    _, _, st = ivf.search(tiny_ds.queries[:8], with_stats=True)
    assert np.all(np.asarray(st.n_dist) >= 12)


# --------------------------------- bugfix 5: n_dist counts the entry seeds
def test_ndist_counts_entry_seeds_all_graph_families(tiny_ds):
    """Seed-inclusive n_dist accounting, pinned exactly.

    On a corpus small enough that L >= n, bitmap mode computes every
    reachable node's distance exactly once, so n_dist must equal the
    BFS-reachable count FROM THE SEED SET (seeds included — the init
    dist_fn call computes them) plus, for quantized families, the exact
    re-rank depth. Pre-fix, ndist started at 0 after the seed distances
    were already computed, undercounting every family by n_entries —
    equivalently, n_dist changed when n_entries changed, which this pins
    against across full/PQ/PQ4/SQ."""
    import collections

    base, n, k = tiny_ds.base, tiny_ds.base.shape[0], 10
    quants = {
        "full": QuantConfig(),
        "pq": QuantConfig(kind="pq", pq_m=16, kmeans_iters=4),
        "pq4": QuantConfig(kind="pq4", pq_m=16, kmeans_iters=4),
        "sq": QuantConfig(kind="sq"),
    }
    for name, q in quants.items():
        cfg = IndexConfig(
            dim=base.shape[1], metric=tiny_ds.metric,
            build=BuildConfig(M=8, knn_k=16, builder="brute",
                              refine_iters=0, reorder="none"),
            search=SearchConfig(L=256, k=k, early_term=False,
                                visited_mode="bitmap"),
            quant=q)
        idx = KBest(cfg).add(base)
        graph = np.asarray(idx.graph)
        per_entries = []
        for e in (1, 8):
            seeds = np.asarray(idx._entry_ids(e, n)).tolist()
            seen, dq = set(seeds), collections.deque(seeds)
            while dq:
                for v in graph[dq.popleft()]:
                    if v >= 0 and int(v) not in seen:
                        seen.add(int(v))
                        dq.append(int(v))
            s = dataclasses.replace(cfg.search, n_entries=e)
            _, _, st = idx.search(tiny_ds.queries[:6], search_cfg=s,
                                  with_stats=True)
            expect = len(seen) + (0 if name == "full" else 4 * k)
            np.testing.assert_array_equal(
                np.asarray(st.n_dist), np.full(6, expect, np.int32),
                err_msg=f"family={name} n_entries={e}")
            per_entries.append(np.asarray(st.n_dist))
        # exhaustive traversal covers the same reachable set regardless of
        # seed count — only seed-EXCLUDING accounting makes these differ
        np.testing.assert_array_equal(per_entries[0], per_entries[1])


def test_ivf_ndist_identity_scanned_plus_rerank(tiny_ds):
    """IVF has no entry seeds; its n_dist stays the exact identity
    scanned codes + valid re-ranked candidates (cross-family units)."""
    import jax.numpy as jnp
    from repro.core import ivf as ivf_mod

    cfg = IndexConfig(
        dim=tiny_ds.base.shape[1], metric=tiny_ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4, list_pad=32),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4),
        search=SearchConfig(L=64, k=10, nprobe=4))
    idx = KBest(cfg).add(tiny_ds.base)
    q = idx._prep_queries(tiny_ds.queries[:6])
    metric = "ip" if cfg.metric == "cosine" else cfg.metric
    _, _, st = idx.search(tiny_ds.queries[:6], with_stats=True)
    wide_L = max(64, 4 * 10)                     # _widen's queue width
    _, cand, probes = ivf_mod.search_ivf(idx.ivf, q, 4, wide_L, metric)
    expect = (np.asarray(ivf_mod.scanned_counts(idx.ivf, probes))
              + np.asarray((cand >= 0).sum(axis=1)))
    np.testing.assert_array_equal(np.asarray(st.n_dist),
                                  expect.astype(np.int32))


# ------------------------------------------------- bugfix 4: k > L widening
def test_k_greater_than_L_widens(tiny_ds, tiny_index):
    assert tiny_index.config.search.L == 64
    d, i = tiny_index.search(tiny_ds.queries[:4], k=128)   # k > L: no crash
    assert d.shape == (4, 128) and i.shape == (4, 128)
    dd = np.asarray(d)
    assert np.all(np.diff(dd, axis=1) >= 0), "results stay sorted"
    # the widened queue really returns k results on a reachable corpus
    assert np.all(np.asarray(i)[:, :64] >= 0)


def test_k_greater_than_L_through_engine(tiny_ds, tiny_index):
    eng = SearchEngine(tiny_index, min_bucket=8, max_bucket=8)
    d, i = eng.search(tiny_ds.queries[:4], k=96)
    assert d.shape == (4, 96)
    d_ref, i_ref = tiny_index.search(tiny_ds.queries[:4], k=96)
    np.testing.assert_array_equal(i, np.asarray(i_ref))
