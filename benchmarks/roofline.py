"""Roofline + cost-model validation against the REAL kernels
(DESIGN.md §16). Replaces the dead seed version that read LM dry-run
artifacts from a nonexistent experiments/dryrun/.

Three parts, all on live 5k runs:

  1. n_dist validation — the static model's closed-form distance-count
     terms vs measured SearchStats.n_dist:
       - ivf/pq8: EXACT per-query equality. Predicted = valid codes in
         the probed lists (from the built index + probe assignment —
         ivf.scanned_counts, NOT search stats, so the check is
         non-circular) + the rerank term min(r, width, scanned).
       - graph/full + graph/pq8: the seed term (n_entries) + rerank
         term decomposition must close with 0 <= traversal <= hops*M
         per query (catches the seed-undercount bug class).
       - graph/pq8 rerank delta: two searches differing ONLY in
         QuantConfig.rerank share an identical traversal, so measured
         n_dist deltas must equal the model's term delta EXACTLY
         (catches the rerank-undercount class, zero profiling).
  2. cost ordering — predicted seconds (max(flops/PEAK, bytes/BW)) over
     an 8-config (nprobe x L) IVF sweep vs measured wall time; the
     smoke lane asserts Spearman >= 0.8. Absolute seconds are never
     asserted (interpret-mode CPU JAX is not a Kunpeng socket); the
     model's job is ORDERING, which is what the tuner prunes with.
  3. roofline table — compute/memory terms, dominant side, arithmetic
     intensity per swept config.

    PYTHONPATH=src python -m benchmarks.roofline                  # report
    PYTHONPATH=src python -m benchmarks.roofline --smoke \
        --out BENCH_cost_smoke.json                               # CI lane
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.analysis import cost
from repro.core import ivf as ivf_mod
from repro.core.index import KBest, prep_queries
from repro.core.types import QuantConfig
from repro.configs import kbest as kcfg
from repro.data.vectors import make_dataset, recall_at_k

SPEARMAN_FLOOR = 0.8
SWEEP_NPROBE = (2, 8, 32, 64)
SWEEP_L = (64, 256)
RERANK_A, RERANK_B = 24, 48


def spearman(a, b) -> float:
    """Rank correlation without scipy (ordinal ranks; the sweep has no
    ties by construction)."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0


def _timed_search(idx, queries, scfg, reps: int = 3) -> float:
    """min-of-reps wall seconds for one full search batch; warms with the
    EXACT timed call shape first (jit keys on shapes + config)."""
    np.asarray(idx.search(queries, search_cfg=scfg)[0])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        d, _ = idx.search(queries, search_cfg=scfg)
        np.asarray(d)          # block until the result is materialized
        best = min(best, time.perf_counter() - t0)
    return best


# ----------------------------------------------------- n_dist validation

def check_ivf_exact(idx, queries, n: int) -> dict:
    """Predicted n_dist (scanned + rerank closed forms) == measured,
    per query."""
    scfg = idx.config.search
    w = cost.workload_from(idx.config, n=n, Q=len(queries))
    state = idx.ivf
    metric = "ip" if idx.config.metric == "cosine" else idx.config.metric
    q = prep_queries(idx.config, queries)
    probes = ivf_mod.select_probes(state, q, scfg.nprobe, metric)
    scanned = np.asarray(ivf_mod.scanned_counts(state, probes))
    predicted = np.array([cost.ivf_n_dist_exact(w, int(s),
                                                nlist=state.nlist,
                                                max_len=state.max_len)
                          for s in scanned])
    _, _, stats = idx.search(queries, with_stats=True)
    measured = np.asarray(stats.n_dist)
    return {"name": "ivf_pq8_exact",
            "n_queries": len(queries),
            "n_mismatch": int((predicted != measured).sum()),
            "predicted_mean": float(predicted.mean()),
            "measured_mean": float(measured.mean())}


def check_graph_decomposition(idx, queries, n: int, label: str) -> dict:
    """seed + traversal + rerank must close with 0 <= traversal <=
    hops*M per query."""
    w = cost.workload_from(idx.config, n=n, Q=len(queries))
    _, _, stats = idx.search(queries, with_stats=True)
    nd = np.asarray(stats.n_dist)
    hops = np.asarray(stats.n_hops)
    seed = w.n_entries
    rerank = cost.graph_rerank_depth(w)
    traversal = nd - seed - rerank
    return {"name": label,
            "n_queries": len(queries),
            "seed_term": seed, "rerank_term": rerank,
            "n_traversal_negative": int((traversal < 0).sum()),
            "n_traversal_over_bound": int((traversal > hops * w.M).sum()),
            "traversal_mean": float(traversal.mean()),
            "measured_mean": float(nd.mean())}


def check_rerank_delta(idx_a, idx_b, queries, n: int) -> dict:
    """Identical traversal, rerank depths a vs b: measured per-query
    n_dist delta must equal the model's rerank-term delta exactly."""
    wa = cost.workload_from(idx_a.config, n=n, Q=len(queries))
    wb = cost.workload_from(idx_b.config, n=n, Q=len(queries))
    model_delta = cost.graph_rerank_depth(wb) - cost.graph_rerank_depth(wa)
    _, _, sa = idx_a.search(queries, with_stats=True)
    _, _, sb = idx_b.search(queries, with_stats=True)
    delta = np.asarray(sb.n_dist) - np.asarray(sa.n_dist)
    return {"name": "graph_pq8_rerank_delta",
            "rerank_a": RERANK_A, "rerank_b": RERANK_B,
            "model_delta": model_delta,
            "n_mismatch": int((delta != model_delta).sum()),
            "measured_delta_mean": float(delta.mean())}


# ------------------------------------------------------ ordering + table

def sweep(idx, ds, n: int, k: int) -> list:
    """(nprobe x L) IVF sweep: predicted roofline terms vs measured
    wall time."""
    state = idx.ivf
    rows = []
    for nprobe in SWEEP_NPROBE:
        for L in SWEEP_L:
            scfg = dataclasses.replace(idx.config.search, nprobe=nprobe,
                                       L=L)
            w = cost.workload_from(idx.config, search=scfg, n=n,
                                   Q=len(ds.queries))
            qc = cost.ivf_search_cost(w, nlist=state.nlist,
                                      max_len=state.max_len)
            wall = _timed_search(idx, ds.queries, scfg)
            _, ids = idx.search(ds.queries, search_cfg=scfg)
            rows.append({
                "nprobe": nprobe, "L": L,
                "pred_s": qc.seconds,
                "t_compute": qc.t_compute, "t_memory": qc.t_memory,
                "dominant": qc.dominant,
                "intensity": qc.flops / qc.hbm_bytes,
                "pred_us_per_q": qc.us_per_query,
                "wall_s": wall,
                "wall_us_per_q": wall / len(ds.queries) * 1e6,
                "recall": recall_at_k(np.asarray(ids), ds.gt_ids, k)})
    return rows


def render(rows) -> str:
    out = [f"{'nprobe':>6} {'L':>4} {'pred us/q':>10} {'wall us/q':>10} "
           f"{'F/B':>6} {'bound':>7} {'recall':>7}"]
    for r in rows:
        out.append(f"{r['nprobe']:>6} {r['L']:>4} "
                   f"{r['pred_us_per_q']:>10.1f} "
                   f"{r['wall_us_per_q']:>10.1f} {r['intensity']:>6.1f} "
                   f"{r['dominant']:>7} {r['recall']:>7.3f}")
    return "\n".join(out)


def main(quick: bool = False, smoke: bool = False,
         out: str = "BENCH_roofline.json") -> dict:
    n, n_queries, k = 5_000, 100, 10
    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=k)

    # --- builds: ivf/pq8 preset, graph/full preset, graph/pq8 pair ----
    ivf_cfg = kcfg.ivf_index_config("deep_like")
    idx_ivf = KBest(ivf_cfg).add(ds.base)

    g_cfg = kcfg.index_config("deep_like")
    idx_full = KBest(g_cfg).add(ds.base)

    pq_cfg = dataclasses.replace(
        g_cfg, quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=6,
                                 rerank=RERANK_A))
    idx_pq = KBest(pq_cfg)
    idx_pq.db, idx_pq.graph, idx_pq.entry, idx_pq.order = (
        idx_full.db, idx_full.graph, idx_full.entry, idx_full.order)
    idx_pq._train_quant(idx_pq.db)
    # rerank sibling: SAME graph + SAME trained codebooks/codes, only the
    # exact-rerank depth differs => traversal identical by construction
    idx_pq2 = KBest(dataclasses.replace(
        pq_cfg, quant=dataclasses.replace(pq_cfg.quant, rerank=RERANK_B)))
    idx_pq2.db, idx_pq2.graph, idx_pq2.entry, idx_pq2.order = (
        idx_pq.db, idx_pq.graph, idx_pq.entry, idx_pq.order)
    idx_pq2.pq, idx_pq2.pq_codes = idx_pq.pq, idx_pq.pq_codes

    # --- part 1: n_dist validation -----------------------------------
    checks = [
        check_ivf_exact(idx_ivf, ds.queries, n),
        check_graph_decomposition(idx_full, ds.queries, n, "graph_full"),
        check_graph_decomposition(idx_pq, ds.queries, n, "graph_pq8"),
        check_rerank_delta(idx_pq, idx_pq2, ds.queries, n),
    ]
    for c in checks:
        bad = sum(v for kk, v in c.items() if kk.startswith("n_mismatch")
                  or kk.startswith("n_traversal"))
        print(f"[{c['name']}] {'OK' if bad == 0 else f'{bad} FAIL'} "
              f"({ {kk: v for kk, v in c.items() if kk != 'name'} })")

    # --- parts 2+3: cost ordering + roofline table -------------------
    rows = sweep(idx_ivf, ds, n, k)
    rho = spearman([r["pred_s"] for r in rows],
                   [r["wall_s"] for r in rows])
    print()
    print(render(rows))
    print(f"\nspearman(predicted cost, measured wall) over {len(rows)} "
          f"configs: {rho:.3f}")

    report = {"n": n, "n_queries": n_queries, "dataset": "deep_like",
              "constants": {"peak_flops": cost.PEAK_FLOPS,
                            "mem_bw": cost.MEM_BW},
              "checks": checks, "sweep": rows, "spearman": rho}
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")

    if smoke:
        for c in checks:
            for kk, v in c.items():
                if kk.startswith(("n_mismatch", "n_traversal")):
                    assert v == 0, f"{c['name']}.{kk} = {v} (want 0)"
        assert rho >= SPEARMAN_FLOOR, \
            f"cost-ordering Spearman {rho:.3f} < {SPEARMAN_FLOOR}"
        print(f"smoke OK: n_dist terms exact, ordering rho={rho:.3f} >= "
              f"{SPEARMAN_FLOOR}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="hard-assert exact n_dist + Spearman floor")
    ap.add_argument("--out", default="BENCH_roofline.json")
    args = ap.parse_args()
    main(quick=args.quick, smoke=args.smoke, out=args.out)
