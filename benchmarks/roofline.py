"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch x shape) on the single-pod mesh (256 chips), derive the three
terms (seconds/step/device; artifacts carry PER-DEVICE numbers from the
partitioned HLO, so "X_total/(chips*rate)" algebraically equals
"X_per_device/rate"):

  compute    = HLO_FLOPs_dev / 197e12      (v5e bf16 peak per chip)
  memory     = HLO_bytes_dev / 819e9       (HBM bandwidth)
  collective = coll_bytes_dev / 50e9       (ICI per-link)

Also: MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (serve) from
launch/specs.py meta, the MODEL/HLO usefulness ratio, the dominant term,
and a one-line improvement note. Output: markdown table (stdout) + the
machine-readable experiments/roofline.json.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "experiments" / "roofline.json"

NOTES = {
    "compute": "raise MXU utilization: larger per-device tiles, fuse "
               "pointwise ops, drop fp32 logits",
    "memory": "cut HBM traffic: flash/chunked attention, masked-position "
              "loss, bf16 intermediates, better remat policy",
    "collective": "reshard to kill resharding collectives: EP-aligned "
                  "token layout, overlap all-to-all with expert GEMMs",
}


def analyze(mesh: str = "pod16x16"):
    rows = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        ce = r.get("cost_extrapolated") or {}
        if "flops" not in ce:
            ce = {"flops": r["cost_analysis"].get("flops", 0.0),
                  "bytes": r["cost_analysis"].get("bytes accessed", 0.0),
                  "coll_bytes": r["collectives"]["total_bytes"],
                  "method": "raw"}
        t_c = ce["flops"] / PEAK_FLOPS
        t_m = ce["bytes"] / HBM_BW
        t_x = ce["coll_bytes"] / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        bound = max(t_c, t_m, t_x)
        mf_dev = r["meta"]["model_flops"] / r["devices"]
        useful = mf_dev / ce["flops"] if ce["flops"] else 0.0
        # roofline fraction: useful model flops per second at the bound,
        # relative to peak — the score §Perf iterates on.
        frac = (mf_dev / bound) / PEAK_FLOPS if bound > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
            "mesh": mesh,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "model_flops_dev": mf_dev,
            "hlo_flops_dev": ce["flops"],
            "useful_ratio": useful,
            "roofline_fraction": frac,
            "temp_bytes_dev": r["memory_analysis"]["temp_bytes"],
            "note": NOTES[dom],
            "method": ce.get("method", "?"),
        })
    return rows


def render(rows) -> str:
    hdr = ("| arch | shape | dom | compute s | memory s | coll s | "
           "MODEL/HLO | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['temp_bytes_dev']/2**30:.1f} |\n")
    return "".join(out)


def main():
    rows = analyze()
    OUT.write_text(json.dumps(rows, indent=1))
    print(render(rows))
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']:24s} {r['shape']:14s} frac={r['roofline_fraction']:.4f} dom={r['dominant']}")
    collb = sorted(rows, key=lambda r: -r["t_collective_s"])[:5]
    print("most collective-bound:")
    for r in collb:
        print(f"  {r['arch']:24s} {r['shape']:14s} t_coll={r['t_collective_s']:.3f}s")


if __name__ == "__main__":
    main()
