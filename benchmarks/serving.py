"""Serving benchmark — closed-loop, open-loop, and overload QPS through
repro.serve.

Beyond-paper section (the paper reports steady-state QPS only; a deployed
service also cares about what variable-size traffic does to the compile
cache and the latency tail):

  closed-loop : back-to-back variable-size batches (offered load = service
                rate). Measures sustained QPS, per-query cost, and that the
                shape-bucketed compile cache absorbs every batch size
                without re-tracing.
  open-loop   : Poisson arrivals at a target rate against a virtual clock
                (single server). Measures queueing latency p50/p95/p99 —
                the number a latency SLO actually binds on.
  overload    : Poisson arrivals at 2x the measured saturation rate with a
                deadline SLO on every request (DESIGN.md §17). Runs the
                SAME traffic twice — no-policy baseline vs admission
                control + degrade ladder + bounded queue — and asserts the
                policy run holds served-sojourn p99 under the SLO, beats
                the baseline's goodput (served-within-deadline QPS), and
                keeps recall at or above the ladder's bottom-rung floor.
                The crash-point save/load matrix (core/persist.py) rides
                along. Writes git-tracked BENCH_serving.json (full) or
                BENCH_serving_smoke.json (--serve-smoke lane).

    PYTHONPATH=src python -m benchmarks.serving [--smoke] [--overload]

--smoke runs a CI-sized corpus and HARD-FAILS (exit 1) if serving many
batch sizes triggers more XLA traces than warmed shape buckets — the
compile-cache regression guard (a re-trace per batch shape is exactly the
anti-pattern the engine exists to prevent).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import numpy as np

ROW = ("{mode},{engine},{requests},{queries},{qps:.1f},{p50:.2f},{p95:.2f},"
       "{p99:.2f},{dists:.0f},{recall},{traces}")
HDR = "mode,engine,requests,queries,qps,p50_ms,p95_ms,p99_ms,dists_per_query,recall,traces"


def build_engines(n: int, n_queries: int, quick: bool):
    from repro.core.index import KBest
    from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                                  QuantConfig, SearchConfig)
    from repro.data.vectors import make_dataset
    from repro.serve import SearchEngine

    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    dim = ds.base.shape[1]
    build = (BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                         reorder="none") if quick else
             BuildConfig(M=32, knn_k=48, refine_iters=1, reorder="mst"))
    graph = KBest(IndexConfig(
        dim=dim, metric=ds.metric, build=build,
        search=SearchConfig(L=64, k=10, early_term=True))).add(ds.base)
    ivf = KBest(IndexConfig(
        dim=dim, metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4 if quick else 8),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4 if quick else 8),
        search=SearchConfig(L=64, k=10, nprobe=8))).add(ds.base)
    engines = {
        "graph": SearchEngine(graph, min_bucket=8, max_bucket=32,
                              name="graph"),
        "ivf": SearchEngine(ivf, min_bucket=8, max_bucket=32, name="ivf"),
    }
    return ds, engines


def _row(mode, name, report_or_stats, qps, p50, p95, p99, recall, traces):
    st = report_or_stats
    print(ROW.format(mode=mode, engine=name, requests=st.n_requests,
                     queries=st.n_queries, qps=qps, p50=p50, p95=p95,
                     p99=p99, dists=st.dists_per_query,
                     recall=("-" if recall is None else f"{recall:.3f}"),
                     traces=traces))


def closed_loop(ds, engines, n_requests: int, seed: int = 0):
    """Back-to-back variable-size batches; returns the ServeReport."""
    from repro.serve import Request, serve_loop
    rng = np.random.default_rng(seed)
    nq = len(ds.queries)
    reqs = []
    for j in range(n_requests):
        b = int(rng.integers(3, 28))
        s = int(rng.integers(0, max(nq - b, 1)))
        reqs.append(Request(queries=ds.queries[s:s + b],
                            gt_ids=ds.gt_ids[s:s + b],
                            engine=str(rng.choice(list(engines)))))
    t0 = time.perf_counter()
    report = serve_loop(engines, reqs)
    wall = time.perf_counter() - t0
    for name, st in sorted(report.engine_stats.items()):
        if st.n_queries == 0:
            continue
        qps = st.n_queries / max(st.mean_lat_ms * st.n_requests / 1e3, 1e-9)
        # PER-ENGINE recall (engine telemetry, gt forwarded by serve_loop)
        # — the blended report.recall_at_k would fabricate identical
        # numbers for both families and defeat cross-family tuning
        _row("closed", name, st, qps, st.lat_p50_ms, st.lat_p95_ms,
             st.lat_p99_ms, st.recall_at_k, st.n_traces)
    print(f"# closed-loop: {report.summary()} | wall {wall:.2f}s "
          f"qps={report.n_served / wall:.1f}")
    return report


def open_loop(ds, engine, rate_qps: float, n_requests: int, seed: int = 0):
    """Poisson arrivals on a virtual clock, single server: request latency =
    queue wait + measured service time. Offered load above the service rate
    shows up as an exploding p99 — the open/closed distinction that
    closed-loop benchmarks famously hide."""
    rng = np.random.default_rng(seed)
    nq = len(ds.queries)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_requests))
    lat, served = [], 0
    t_free = 0.0
    for a in arrivals:
        b = int(rng.integers(3, 28))
        s = int(rng.integers(0, max(nq - b, 1)))
        t0 = time.perf_counter()
        engine.search(ds.queries[s:s + b])
        service = time.perf_counter() - t0
        start = max(a, t_free)
        t_free = start + service
        lat.append((t_free - a) * 1e3)
        served += b
    lat = np.asarray(lat)
    st = engine.stats()
    offered_qps = rate_qps * served / n_requests    # requests/s * mean batch
    _row("open", engine.name, st, offered_qps,
         float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
         float(np.percentile(lat, 99)), None, st.n_traces)
    return lat


def _build_overload_engine(n: int, n_queries: int, quick: bool):
    """Single IVF+PQ server for the overload ramp (fast to build, and the
    family with the deepest degrade ladder)."""
    from repro.configs import kbest as kcfg
    from repro.core.index import KBest
    from repro.data.vectors import make_dataset
    from repro.serve import SearchEngine

    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    cfg = kcfg.ivf_index_config("deep_like")
    cfg = dataclasses.replace(
        cfg, dim=ds.base.shape[1],
        ivf=dataclasses.replace(cfg.ivf, kmeans_iters=4 if quick else 8),
        quant=dataclasses.replace(cfg.quant,
                                  kmeans_iters=4 if quick else 8))
    eng = SearchEngine(KBest(cfg).add(ds.base), min_bucket=8, max_bucket=32,
                       name="default")
    return ds, eng


def _calibrate(ds, eng, ladder, batch: int):
    """Warm every ladder rung's compiled programs (each rung is a distinct
    XLA program per shape bucket) and feed measured dispatch times to the
    LatencyModel so admission predicts from calibrated priors, not the
    cost model's arbitrary absolute scale. Returns (model, s_ms) where
    s_ms is the median measured service time of a `batch`-row dispatch at
    the base rung."""
    from repro.serve import LatencyModel
    model = LatencyModel(slack=1.5)
    for rung in ladder:
        eng.warmup(search_cfg=rung)
        for rows in (batch, eng.max_bucket):
            for _ in range(3):
                t0 = time.perf_counter()
                eng.search(ds.queries[:rows], search_cfg=rung)
                model.observe(eng, rung, rows,
                              (time.perf_counter() - t0) * 1e3)
    samples = []
    for _ in range(5):
        t0 = time.perf_counter()
        eng.search(ds.queries[:batch], search_cfg=ladder[0])
        samples.append((time.perf_counter() - t0) * 1e3)
    return model, float(np.median(samples))


def overload(ds, eng, n_requests: int, batch: int = 8, seed: int = 0):
    """2x-saturation Poisson ramp, baseline vs policy on identical traffic.

    Coalescing is OFF for both runs so every dispatch is exactly `batch`
    rows — the shape admission calibrated against — making this a clean
    single-server M/D/1-style comparison (DESIGN.md §17).
    """
    from repro.configs import kbest as kcfg
    from repro.data.vectors import recall_at_k
    from repro.serve import DegradePolicy, Request, serve_loop

    ladder = kcfg.degrade_ladder(eng.index.config)
    model, s_ms = _calibrate(ds, eng, ladder, batch)
    capacity_qps = batch / (s_ms / 1e3)
    offered_qps = 2.0 * capacity_qps
    slo_ms = max(6.0 * s_ms, 20.0)

    # bottom-rung offline recall — the floor degraded serving must hold
    _, ids = eng.index.search(ds.queries, search_cfg=ladder[-1])
    floor = recall_at_k(np.asarray(ids), ds.gt_ids, ladder[-1].k)

    rng = np.random.default_rng(seed)
    arrivals_ms = np.cumsum(
        rng.exponential(batch / offered_qps, size=n_requests)) * 1e3
    starts = np.random.default_rng(seed + 1).integers(
        0, len(ds.queries) - batch + 1, size=n_requests)

    def make_requests():
        return [Request(queries=ds.queries[s:s + batch],
                        gt_ids=ds.gt_ids[s:s + batch], request_id=i,
                        arrival_ms=float(a), deadline_ms=slo_ms)
                for i, (a, s) in enumerate(zip(arrivals_ms, starts))]

    def goodput_qps(rep):
        ok = sum(r.n_served for r in rep.results
                 if r.status == "ok" and not r.deadline_missed)
        return ok / (max(rep.t_end_ms, float(arrivals_ms[-1])) / 1e3)

    def run_row(rep, mode):
        n_ok = sum(1 for r in rep.results if r.status == "ok")
        return {
            "mode": mode, "n_requests": rep.n_requests, "n_ok": n_ok,
            "n_rejected": rep.n_rejected, "n_shed": rep.n_shed,
            "n_failed": rep.n_failed,
            "n_deadline_missed": rep.n_deadline_missed,
            "goodput_qps": round(goodput_qps(rep), 1),
            "sojourn_p50_ms": round(rep.sojourn_p50_ms, 3),
            "sojourn_p99_ms": round(rep.sojourn_p99_ms, 3),
            "recall_served": (None if rep.recall_at_k is None
                              else round(rep.recall_at_k, 4)),
        }

    eng.reset_stats()
    base = serve_loop(eng, make_requests(), coalesce=False, admission=False)
    base_row = run_row(base, "baseline")

    eng.reset_stats()
    policy = DegradePolicy(ladder=tuple(ladder), high_ms=0.3 * slo_ms,
                           low_ms=0.05 * slo_ms, patience=2)
    pol = serve_loop(eng, make_requests(), coalesce=False, admission=True,
                     latency_model=model, degrade=policy,
                     max_queue=max(4, n_requests // 10))
    pol_row = run_row(pol, "policy")
    pol_row["degrade_transitions"] = len(policy.transitions)
    pol_row["degrade_occupancy"] = {
        str(k): v for k, v in sorted(policy.occupancy.items())}

    result = {
        "batch": batch, "n_requests": n_requests,
        "service_ms_base": round(s_ms, 3),
        "capacity_qps": round(capacity_qps, 1),
        "offered_qps": round(offered_qps, 1), "slo_ms": round(slo_ms, 3),
        "ladder": [f"L={r.L},nprobe={r.nprobe},rf={r.rescore_factor}"
                   for r in ladder],
        "floor_recall": round(floor, 4),
        "runs": [base_row, pol_row],
    }
    print(f"# overload: capacity={capacity_qps:.0f}qps "
          f"offered={offered_qps:.0f}qps slo={slo_ms:.1f}ms "
          f"floor_recall={floor:.3f}")
    for row in (base_row, pol_row):
        print(f"#   {row['mode']}: goodput={row['goodput_qps']}qps "
              f"p99={row['sojourn_p99_ms']}ms ok={row['n_ok']} "
              f"rej={row['n_rejected']} shed={row['n_shed']} "
              f"miss={row['n_deadline_missed']} "
              f"recall={row['recall_served']}")

    # --- hard assertions (ISSUE acceptance criteria) ---
    problems = []
    if pol_row["sojourn_p99_ms"] > slo_ms:
        problems.append(f"policy served p99 {pol_row['sojourn_p99_ms']}ms "
                        f"exceeds SLO {slo_ms:.1f}ms")
    if pol_row["goodput_qps"] <= base_row["goodput_qps"]:
        problems.append(f"policy goodput {pol_row['goodput_qps']} <= "
                        f"baseline {base_row['goodput_qps']}")
    if (pol_row["recall_served"] is not None
            and pol_row["recall_served"] < floor - 0.02):
        problems.append(f"served recall {pol_row['recall_served']} below "
                        f"ladder floor {floor:.3f} - 0.02")
    if problems:
        raise RuntimeError("OVERLOAD POLICY REGRESSION: "
                           + "; ".join(problems))
    print("# overload: policy holds SLO, beats baseline goodput, "
          "recall above ladder floor (ok)")
    return result


def crash_matrix() -> dict:
    """Kill a save at every checkpoint; load must return the previous
    intact index, the fully-committed new one, or raise IndexCorruptError
    — never garbage. The bench-side twin of tests/test_crashsafe.py."""
    from repro.configs import kbest as kcfg
    from repro.core.index import KBest
    from repro.core.persist import IndexCorruptError
    from repro.core.sharded import ShardedKBest
    from repro.serve.faults import InjectedCrash, crash_at, trace_steps

    rng = np.random.default_rng(0)
    x = rng.standard_normal((160, 32)).astype(np.float32)
    y = rng.standard_normal((160, 32)).astype(np.float32)

    def db_of(idx):
        if hasattr(idx, "shards"):
            return np.concatenate([s.db for s in idx.shards])
        return idx.db

    cases = [
        ("single", KBest, kcfg.smoke_config()),
        ("sharded", ShardedKBest, kcfg.sharded_smoke_config(n_shards=2)),
    ]
    out = {}
    with tempfile.TemporaryDirectory() as td:
        for name, cls, cfg in cases:
            old = cls(cfg).add(x)
            new = cls(cfg).add(y)
            steps = []
            with trace_steps(steps):
                new.save(os.path.join(td, name + "_probe"))
            counts = {"steps": len(steps), "old": 0, "new": 0, "error": 0}
            path = os.path.join(td, name)
            for step in steps:
                old.save(path)
                with crash_at(step):
                    try:
                        new.save(path)
                    except InjectedCrash:
                        pass
                try:
                    loaded = cls.load(path)
                except (IndexCorruptError, FileNotFoundError):
                    counts["error"] += 1
                    continue
                db = db_of(loaded)
                if np.array_equal(db, db_of(old)):
                    counts["old"] += 1
                elif np.array_equal(db, db_of(new)):
                    counts["new"] += 1
                else:
                    raise RuntimeError(
                        f"CRASH-SAFETY REGRESSION: garbage load after "
                        f"kill at checkpoint {step!r} ({name})")
            out[name] = counts
            print(f"# crash-matrix {name}: {counts}")
    return out


def overload_main(smoke: bool = False, out: str | None = None,
                  seed: int = 0) -> dict:
    n, n_queries, n_requests = (1500, 64, 80) if smoke else (8000, 200, 240)
    ds, eng = _build_overload_engine(n, n_queries, quick=smoke)
    result = {
        "bench": "serving-overload", "schema": 1, "smoke": smoke,
        "n": n, "seed": seed,
        "overload": overload(ds, eng, n_requests, seed=seed),
        "crash_matrix": crash_matrix(),
    }
    for counts in result["crash_matrix"].values():
        if counts["old"] + counts["error"] == 0:
            raise RuntimeError("CRASH-SAFETY REGRESSION: no kill point "
                               "preserved the previous index or raised "
                               f"cleanly: {result['crash_matrix']}")
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {out}")
    return result


def main(smoke: bool = False, n: int = 8000, n_queries: int = 200,
         n_requests: int = 40) -> None:
    if smoke:
        n, n_queries, n_requests = 1200, 60, 12
    ds, engines = build_engines(n, n_queries, quick=smoke)

    # precompile the ladder once; serving must then never trace again
    for e in engines.values():
        e.warmup()
    traces_after_warmup = {k: e.n_traces for k, e in engines.items()}
    print(f"# warmup traces: {traces_after_warmup}")
    print(HDR)

    closed_loop(ds, engines, n_requests)
    engines["graph"].reset_stats()      # clean accounting for the open loop
    open_loop(ds, engines["graph"], rate_qps=2.0 if smoke else 10.0,
              n_requests=max(6, n_requests // 2), seed=1)

    fresh = {k: e.n_traces - traces_after_warmup[k]
             for k, e in engines.items()}
    if any(fresh.values()):
        msg = (f"COMPILE-CACHE REGRESSION: serving traced fresh XLA programs "
               f"after warmup: {fresh} — every batch size must land in a "
               f"warmed shape bucket")
        if smoke:
            # raise (not sys.exit) so benchmarks/run.py's per-section
            # harness can record the failure; the CLI still exits 1
            raise RuntimeError(msg)
        print("WARNING:", msg)
    else:
        print("# compile cache: 0 fresh traces after warmup (ok)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + hard compile-cache assertion")
    ap.add_argument("--overload", action="store_true",
                    help="2x-saturation ramp: baseline vs admission+degrade"
                         " policy, plus the crash-point save/load matrix")
    ap.add_argument("--out", default=None,
                    help="JSON output path for --overload (default: "
                         "BENCH_serving.json, or BENCH_serving_smoke.json "
                         "with --smoke)")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--requests", type=int, default=40)
    args = ap.parse_args()
    if args.overload:
        dest = args.out or ("BENCH_serving_smoke.json" if args.smoke
                            else "BENCH_serving.json")
        overload_main(smoke=args.smoke, out=dest)
    else:
        main(smoke=args.smoke, n=args.n, n_requests=args.requests)
