"""Serving benchmark — closed-loop and open-loop QPS through repro.serve.

Beyond-paper section (the paper reports steady-state QPS only; a deployed
service also cares about what variable-size traffic does to the compile
cache and the latency tail):

  closed-loop : back-to-back variable-size batches (offered load = service
                rate). Measures sustained QPS, per-query cost, and that the
                shape-bucketed compile cache absorbs every batch size
                without re-tracing.
  open-loop   : Poisson arrivals at a target rate against a virtual clock
                (single server). Measures queueing latency p50/p95/p99 —
                the number a latency SLO actually binds on.

    PYTHONPATH=src python -m benchmarks.serving [--smoke]

--smoke runs a CI-sized corpus and HARD-FAILS (exit 1) if serving many
batch sizes triggers more XLA traces than warmed shape buckets — the
compile-cache regression guard (a re-trace per batch shape is exactly the
anti-pattern the engine exists to prevent).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

ROW = ("{mode},{engine},{requests},{queries},{qps:.1f},{p50:.2f},{p95:.2f},"
       "{p99:.2f},{dists:.0f},{recall},{traces}")
HDR = "mode,engine,requests,queries,qps,p50_ms,p95_ms,p99_ms,dists_per_query,recall,traces"


def build_engines(n: int, n_queries: int, quick: bool):
    from repro.core.index import KBest
    from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                                  QuantConfig, SearchConfig)
    from repro.data.vectors import make_dataset
    from repro.serve import SearchEngine

    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    dim = ds.base.shape[1]
    build = (BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                         reorder="none") if quick else
             BuildConfig(M=32, knn_k=48, refine_iters=1, reorder="mst"))
    graph = KBest(IndexConfig(
        dim=dim, metric=ds.metric, build=build,
        search=SearchConfig(L=64, k=10, early_term=True))).add(ds.base)
    ivf = KBest(IndexConfig(
        dim=dim, metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(kmeans_iters=4 if quick else 8),
        quant=QuantConfig(kind="pq", pq_m=16, kmeans_iters=4 if quick else 8),
        search=SearchConfig(L=64, k=10, nprobe=8))).add(ds.base)
    engines = {
        "graph": SearchEngine(graph, min_bucket=8, max_bucket=32,
                              name="graph"),
        "ivf": SearchEngine(ivf, min_bucket=8, max_bucket=32, name="ivf"),
    }
    return ds, engines


def _row(mode, name, report_or_stats, qps, p50, p95, p99, recall, traces):
    st = report_or_stats
    print(ROW.format(mode=mode, engine=name, requests=st.n_requests,
                     queries=st.n_queries, qps=qps, p50=p50, p95=p95,
                     p99=p99, dists=st.dists_per_query,
                     recall=("-" if recall is None else f"{recall:.3f}"),
                     traces=traces))


def closed_loop(ds, engines, n_requests: int, seed: int = 0):
    """Back-to-back variable-size batches; returns the ServeReport."""
    from repro.serve import Request, serve_loop
    rng = np.random.default_rng(seed)
    nq = len(ds.queries)
    reqs = []
    for j in range(n_requests):
        b = int(rng.integers(3, 28))
        s = int(rng.integers(0, max(nq - b, 1)))
        reqs.append(Request(queries=ds.queries[s:s + b],
                            gt_ids=ds.gt_ids[s:s + b],
                            engine=str(rng.choice(list(engines)))))
    t0 = time.perf_counter()
    report = serve_loop(engines, reqs)
    wall = time.perf_counter() - t0
    for name, st in sorted(report.engine_stats.items()):
        if st.n_queries == 0:
            continue
        qps = st.n_queries / max(st.mean_lat_ms * st.n_requests / 1e3, 1e-9)
        # PER-ENGINE recall (engine telemetry, gt forwarded by serve_loop)
        # — the blended report.recall_at_k would fabricate identical
        # numbers for both families and defeat cross-family tuning
        _row("closed", name, st, qps, st.lat_p50_ms, st.lat_p95_ms,
             st.lat_p99_ms, st.recall_at_k, st.n_traces)
    print(f"# closed-loop: {report.summary()} | wall {wall:.2f}s "
          f"qps={report.n_served / wall:.1f}")
    return report


def open_loop(ds, engine, rate_qps: float, n_requests: int, seed: int = 0):
    """Poisson arrivals on a virtual clock, single server: request latency =
    queue wait + measured service time. Offered load above the service rate
    shows up as an exploding p99 — the open/closed distinction that
    closed-loop benchmarks famously hide."""
    rng = np.random.default_rng(seed)
    nq = len(ds.queries)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_requests))
    lat, served = [], 0
    t_free = 0.0
    for a in arrivals:
        b = int(rng.integers(3, 28))
        s = int(rng.integers(0, max(nq - b, 1)))
        t0 = time.perf_counter()
        engine.search(ds.queries[s:s + b])
        service = time.perf_counter() - t0
        start = max(a, t_free)
        t_free = start + service
        lat.append((t_free - a) * 1e3)
        served += b
    lat = np.asarray(lat)
    st = engine.stats()
    offered_qps = rate_qps * served / n_requests    # requests/s * mean batch
    _row("open", engine.name, st, offered_qps,
         float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
         float(np.percentile(lat, 99)), None, st.n_traces)
    return lat


def main(smoke: bool = False, n: int = 8000, n_queries: int = 200,
         n_requests: int = 40) -> None:
    if smoke:
        n, n_queries, n_requests = 1200, 60, 12
    ds, engines = build_engines(n, n_queries, quick=smoke)

    # precompile the ladder once; serving must then never trace again
    for e in engines.values():
        e.warmup()
    traces_after_warmup = {k: e.n_traces for k, e in engines.items()}
    print(f"# warmup traces: {traces_after_warmup}")
    print(HDR)

    closed_loop(ds, engines, n_requests)
    engines["graph"].reset_stats()      # clean accounting for the open loop
    open_loop(ds, engines["graph"], rate_qps=2.0 if smoke else 10.0,
              n_requests=max(6, n_requests // 2), seed=1)

    fresh = {k: e.n_traces - traces_after_warmup[k]
             for k, e in engines.items()}
    if any(fresh.values()):
        msg = (f"COMPILE-CACHE REGRESSION: serving traced fresh XLA programs "
               f"after warmup: {fresh} — every batch size must land in a "
               f"warmed shape bucket")
        if smoke:
            # raise (not sys.exit) so benchmarks/run.py's per-section
            # harness can record the failure; the CLI still exits 1
            raise RuntimeError(msg)
        print("WARNING:", msg)
    else:
        print("# compile cache: 0 fresh traces after warmup (ok)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + hard compile-cache assertion")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--requests", type=int, default=40)
    args = ap.parse_args()
    main(smoke=args.smoke, n=args.n, n_requests=args.requests)
