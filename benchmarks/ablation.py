"""Ablation study (paper Fig. 7): progressively enable each optimization.

  base        : raw kNN-graph (top-M), plain traversal, jnp per-pair path
  +index      : A1 refinement (selection + search passes + 2-hop)
  +early_term : A3 early termination (tuned t / patience)
  +simd       : H1 batched-distance path (the Pallas batch kernel route;
                on CPU the measurable effect is the batched (Q,M,d) einsum
                versus a per-neighbor python loop — reported as both QPS
                and the count of kernel invocations)
  +prefetch   : H2 fused gather+distance path (scalar-prefetch kernel) +
                A2 MST reorder (locality the prefetch engine exploits)

Metrics: recall, distance computations/query, hops/query, CPU QPS
(relative), and `locality` = mean |id gap| between successively expanded
nodes (the reorder payoff a DMA engine would see).

`quant_ablation` extends the study along the A4 axis (DESIGN.md §13/§14):
the same graph searched over every registered quantization family
(quantize.quant_variants — full vectors, 8-bit PQ, 4-bit fast-scan PQ with
and without u8 LUT requantization, SQ, and the 1-bit sign codec) — recall
vs code bytes/vector, the memory/recall trade the compressed families
exist for.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core.index import KBest
from repro.core.types import BuildConfig, IndexConfig, SearchConfig
from repro.data.vectors import make_dataset, recall_at_k

STAGES = ("base", "+index", "+early_term", "+simd", "+prefetch")


def _index_for(stage: str, ds):
    refined = stage != "base"
    b = BuildConfig(
        M=32, knn_k=48, builder="brute",
        select_rule="alpha" if refined else "none",
        search_passes=2 if refined else 0,
        refine_iters=1 if refined else 0,
        reorder="mst" if stage == "+prefetch" else "none")
    cfg = IndexConfig(dim=ds.base.shape[1], metric=ds.metric, build=b,
                      search=SearchConfig(L=64, k=10))
    return KBest(cfg).add(ds.base)


def _slow_per_pair_dist(db, metric):
    """The UNbatched 1-to-1 path the paper's SIMD batching replaces: one
    lane, one neighbor at a time (python loop over M under jit via scan)."""
    from repro.core.distance import one_to_many

    def fn(queries, nbr_ids):
        def per_query(q, ids):
            def per_nbr(carry, nid):
                v = db[jnp.maximum(nid, 0)]
                d = one_to_many(q, v[None, :], metric)[0]
                return carry, d
            _, ds_ = __import__("jax").lax.scan(per_nbr, 0, ids)
            return ds_
        import jax
        return jax.vmap(per_query)(queries, nbr_ids)
    return fn


def run(n: int = 3000, n_queries: int = 80, seed: int = 0,
        dataset: str = "bigann_like", quick: bool = False):
    if quick:
        n, n_queries = 1500, 40
    ds = make_dataset(dataset, n=n, n_queries=n_queries, k=10)
    rows = []
    idx_cache = {}
    for stage_i, stage in enumerate(STAGES):
        build_key = ("base" if stage == "base"
                     else "+prefetch" if stage == "+prefetch" else "+index")
        if build_key not in idx_cache:
            idx_cache[build_key] = _index_for(build_key, ds)
        idx = idx_cache[build_key]

        et = stage_i >= 2
        # NOTE on timing: stages >= "+simd" use the batched (Q, M, d) path;
        # earlier stages use the per-pair scan. The Pallas kernels
        # (batch_dist / gather_dist) are the TPU lowering of that batched
        # path — on this CPU container they run in interpret mode whose
        # wall-clock is meaningless, so timing uses the XLA-compiled
        # batched einsum (identical math, tests assert bit-parity) and the
        # kernels' correctness is covered by tests/test_kernels.py.
        scfg = SearchConfig(L=64, k=10, early_term=et, et_patience=16,
                            dist_impl="ref")
        if stage_i < 3:   # base / +index / +early_term: per-pair distances
            metric = "ip" if ds.metric != "l2" else "l2"
            dist_fn = _slow_per_pair_dist(idx.db, metric)
            from repro.core import search as smod
            ids_entry = idx._entry_ids(scfg.n_entries, idx.db.shape[0])
            t0 = time.perf_counter()
            d, i, st = smod.search(idx.graph, jnp.asarray(
                ds.queries if ds.metric == "l2" else
                np.asarray(ds.queries)), ids_entry, dist_fn=dist_fn,
                cfg=scfg, n_total=idx.db.shape[0])
            np.asarray(d)
            dt = time.perf_counter() - t0
            if idx.order is not None:
                order = jnp.asarray(idx.order, dtype=jnp.int32)
                i = jnp.where(i >= 0, order[jnp.maximum(i, 0)], -1)
        else:
            t0 = time.perf_counter()
            d, i, st = idx.search(ds.queries, search_cfg=scfg,
                                  with_stats=True)
            np.asarray(d)
            dt = time.perf_counter() - t0
        rows.append({
            "stage": stage,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, 10),
            "dists": float(np.asarray(st.n_dist).mean()),
            "hops": float(np.asarray(st.n_hops).mean()),
            "qps_cpu": n_queries / dt,
            "locality": _graph_locality(idx),
        })
    return rows


def _graph_locality(idx) -> float:
    """Mean |pi(u) - pi(v)| over graph edges in the stored layout."""
    from repro.core.reorder import bandwidth_stats
    return bandwidth_stats(np.asarray(idx.graph))["mean_gap"]


# THE shared quant-kind registry (core/quantize.py) — a kind added there
# (and to types.QUANT_KINDS) appears in this sweep and in core/tune.py's
# tune_quant_kind automatically; tests assert the registry covers
# QUANT_KINDS so the two can never drift apart again.
QUANT_VARIANTS = qz.quant_variants(pq_m=16)


def quant_ablation(n: int = 2000, n_queries: int = 60,
                   dataset: str = "bigann_like", quick: bool = False):
    """The A4 axis: one graph build, every quantization family over it.

    Reports recall (after each family's exact re-rank), code bytes/vector
    and dists/query — the memory/recall/compute triangle of DESIGN.md §13.
    """
    from benchmarks.qps_recall import code_bytes_per_vector
    from repro.core.types import QuantConfig

    if quick:
        n, n_queries = 1500, 40
    ds = make_dataset(dataset, n=n, n_queries=n_queries, k=10)
    b = BuildConfig(M=32, knn_k=48, builder="brute", select_rule="alpha",
                    search_passes=1, refine_iters=1, reorder="none")
    base_cfg = IndexConfig(dim=ds.base.shape[1], metric=ds.metric, build=b,
                           search=SearchConfig(L=64, k=10, early_term=False))
    base = KBest(base_cfg).add(ds.base)     # the one graph build
    rows = []
    for name, qkw in QUANT_VARIANTS.items():
        cfg = dataclasses.replace(base_cfg,
                                  quant=QuantConfig(kmeans_iters=6, **qkw))
        # graph construction is quant-independent: share the built graph
        # and train only the quantizer per variant
        idx = KBest(cfg)
        idx.db, idx.graph, idx.entry, idx.order = (base.db, base.graph,
                                                   base.entry, base.order)
        idx._train_quant(idx.db)
        idx.search(ds.queries[:8], with_stats=True)     # warmup/compile
        t0 = time.perf_counter()
        d, i, st = idx.search(ds.queries, with_stats=True)
        np.asarray(d)
        dt = time.perf_counter() - t0
        rows.append({
            "quant": name,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, 10),
            "dists": float(np.asarray(st.n_dist).mean()),
            "code_bytes": code_bytes_per_vector(idx),
            "qps_cpu": n_queries / dt,
        })
    return rows


def main(quick=False):
    rows = run(quick=quick)
    print("stage,recall,dists_per_q,hops,qps_cpu,locality")
    for r in rows:
        print(f"{r['stage']},{r['recall']:.3f},{r['dists']:.0f},"
              f"{r['hops']:.1f},{r['qps_cpu']:.2f},{r['locality']:.0f}")
    base = rows[0]["qps_cpu"]
    print("\nspeedup over base:",
          " ".join(f"{r['stage']}={r['qps_cpu']/base:.2f}x" for r in rows))
    qrows = quant_ablation(quick=quick)
    print("\nquant,recall,dists_per_q,code_bytes,qps_cpu")
    for r in qrows:
        print(f"{r['quant']},{r['recall']:.3f},{r['dists']:.0f},"
              f"{r['code_bytes']},{r['qps_cpu']:.2f}")
    return rows + qrows


if __name__ == "__main__":
    main()
