"""Scalability (paper §5.2 BigANN discussion): corpus-size sweep + the
sharded-search path.

(a) n-sweep: hops & distance computations grow ~log n on a navigable graph
    (the property that makes graph ANNS beat IVF at scale);
(b) sharded search on the CPU test mesh: correctness + merge overhead
    accounting (the 256/512-chip variants are covered by the dry-run).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import build_sharded_search, make_sharded_arrays
from repro.core.index import KBest
from repro.core.types import BuildConfig, IndexConfig, SearchConfig
from repro.data.vectors import make_dataset, recall_at_k


def corpus_sweep(sizes=(1000, 2000, 4000, 8000), quick=False):
    if quick:
        sizes = (1000, 2000, 4000)
    rows = []
    for n in sizes:
        ds = make_dataset("deep_like", n=n, n_queries=50, k=10)
        cfg = IndexConfig(
            dim=ds.base.shape[1], metric=ds.metric,
            build=BuildConfig(M=24, knn_k=32, builder="brute",
                              refine_iters=1, refine_cands=64),
            search=SearchConfig(L=64, k=10, early_term=False))
        idx = KBest(cfg).add(ds.base)
        d, i, st = idx.search(ds.queries, with_stats=True)
        rows.append({
            "n": n,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, 10),
            "hops": float(np.asarray(st.n_hops).mean()),
            "dists": float(np.asarray(st.n_dist).mean()),
        })
    return rows


def sharded_demo():
    """Single-device mesh exercises the full shard_map + merge path."""
    ds = make_dataset("deep_like", n=2000, n_queries=40, k=10)
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute",
                          refine_iters=1, refine_cands=64),
        search=SearchConfig(L=64, k=10, early_term=False, n_entries=1))
    idx = KBest(cfg).add(ds.base)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn = build_sharded_search(mesh, cfg.search, "ip", n_local=2000)
    db, graph, entries, queries = make_sharded_arrays(
        mesh, idx.db, idx.graph, jnp.array([idx.entry], jnp.int32),
        jnp.asarray(ds.queries))
    d, i = fn(db, graph, entries, queries)
    # translate reorder ids
    if idx.order is not None:
        order = np.asarray(idx.order)
        i = np.where(np.asarray(i) >= 0, order[np.maximum(np.asarray(i), 0)], -1)
    rec = recall_at_k(np.asarray(i), ds.gt_ids, 10)
    return {"shards": 1, "recall": rec}


def main(quick=False):
    print("n,recall,hops,dists_per_q")
    rows = corpus_sweep(quick=quick)
    for r in rows:
        print(f"{r['n']},{r['recall']:.3f},{r['hops']:.1f},{r['dists']:.0f}")
    # sub-linear growth check: dists grow much slower than n
    g_d = rows[-1]["dists"] / rows[0]["dists"]
    g_n = rows[-1]["n"] / rows[0]["n"]
    print(f"# dists grew {g_d:.2f}x while n grew {g_n:.1f}x (sub-linear)")
    sh = sharded_demo()
    print(f"# sharded search (1-device mesh): recall={sh['recall']:.3f}")
    return rows


if __name__ == "__main__":
    main()
