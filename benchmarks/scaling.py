"""Scalability (paper §5.2 BigANN discussion): corpus-size sweep + the
sharded-index sweep (DESIGN.md §12).

(a) n-sweep: hops & distance computations grow ~log n on a navigable graph
    (the property that makes graph ANNS beat IVF at scale);
(b) shard sweep: ShardedKBest over shards x {graph, ivf} x {full, pq4} on
    the CPU mesh — recall, total dists/query (the merge's cost side), and
    wall time per config, written to BENCH_scaling.json. "full" for the
    IVF family means 8-bit PQ with full-queue exact re-rank (IVF has no
    codeless mode; pq8 is its full-width baseline). Structural invariants
    are hard-asserted the way the pq4 smoke lane asserts its byte claim:
    1-shard results must be bit-identical to the single index, and multi-
    shard recall must be >= the single index at equal per-shard L.
    The physical-device lowering of the same merge (build_sharded_search's
    shard_map path) is covered by the 256/512-chip dry-run.

CLI:
    PYTHONPATH=src python -m benchmarks.scaling                  # full
    PYTHONPATH=src python -m benchmarks.scaling --smoke \
        --out BENCH_scaling.json                                 # CI lane
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.index import KBest
from repro.core.sharded import ShardedKBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import make_dataset, recall_at_k


def corpus_sweep(sizes=(1000, 2000, 4000, 8000), quick=False):
    if quick:
        sizes = (1000, 2000, 4000)
    rows = []
    for n in sizes:
        ds = make_dataset("deep_like", n=n, n_queries=50, k=10)
        cfg = IndexConfig(
            dim=ds.base.shape[1], metric=ds.metric,
            build=BuildConfig(M=24, knn_k=32, builder="brute",
                              refine_iters=1, refine_cands=64),
            search=SearchConfig(L=64, k=10, early_term=False))
        idx = KBest(cfg).add(ds.base)
        d, i, st = idx.search(ds.queries, with_stats=True)
        rows.append({
            "n": n,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, 10),
            "hops": float(np.asarray(st.n_hops).mean()),
            "dists": float(np.asarray(st.n_dist).mean()),
        })
    return rows


def _shard_cfg(family: str, quant: str, dim: int, metric: str,
               n_shards: int) -> IndexConfig:
    """One tuned small-corpus config per (family, quant) cell of the sweep;
    per-shard search knobs (L, nprobe) are held constant across shard
    counts so the sweep isolates the mesh dimension."""
    if family == "graph":
        q = (QuantConfig() if quant == "full"
             else QuantConfig(kind="pq4", pq_m=8, kmeans_iters=4))
        return IndexConfig(
            dim=dim, metric=metric, n_shards=n_shards, quant=q,
            build=BuildConfig(M=24, knn_k=32, builder="brute",
                              refine_iters=1, refine_cands=64),
            search=SearchConfig(L=64, k=10, early_term=False, n_entries=4))
    # ivf: "full" = 8-bit PQ + full-queue exact re-rank (see module doc)
    q = (QuantConfig(kind="pq", pq_m=16, kmeans_iters=5) if quant == "full"
         else QuantConfig(kind="pq4", pq_m=16, kmeans_iters=5))
    return IndexConfig(
        dim=dim, metric=metric, index_type="ivf", n_shards=n_shards,
        ivf=IVFConfig(nlist=0, kmeans_iters=5, list_pad=32), quant=q,
        search=SearchConfig(L=96, k=10, nprobe=12))


def shard_sweep(shards=(1, 2, 4), n=2000, n_queries=40, smoke=False):
    """shards x {graph, ivf} x {full, pq4} rows + the structural asserts."""
    if smoke:
        shards, n, n_queries = (1, 2), 1200, 24
    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    dim, metric = ds.base.shape[1], ds.metric
    rows = []
    for family in ("graph", "ivf"):
        for quant in ("full", "pq4"):
            cfg1 = _shard_cfg(family, quant, dim, metric, 1)
            single = KBest(cfg1).add(ds.base)
            d0, i0, st0 = single.search(ds.queries, with_stats=True)
            base_recall = recall_at_k(np.asarray(i0), ds.gt_ids, 10)
            for p in shards:
                idx = ShardedKBest(cfg1, n_shards=p).add(ds.base)
                # untimed warmup: the first call pays the jit trace +
                # compile (which itself grows with P as the shard loop
                # unrolls); wall_ms must track search cost, not XLA
                d, i, st = idx.search(ds.queries, with_stats=True)
                np.asarray(d), np.asarray(i)
                t0 = time.perf_counter()
                d, i, st = idx.search(ds.queries, with_stats=True)
                np.asarray(d), np.asarray(i)
                wall_ms = (time.perf_counter() - t0) * 1e3
                rec = recall_at_k(np.asarray(i), ds.gt_ids, 10)
                dpq = float(np.asarray(st.n_dist).mean())
                rows.append({
                    "family": family, "quant": quant, "shards": p,
                    "recall": rec, "single_recall": base_recall,
                    "dists_per_query": dpq,
                    "wall_ms": wall_ms,
                })
                if p == 1:
                    # 1-shard mesh == the single index, bit for bit
                    assert np.array_equal(np.asarray(i), np.asarray(i0)) \
                        and np.array_equal(np.asarray(d), np.asarray(d0)), \
                        f"1-shard {family}/{quant} diverged from KBest"
                else:
                    # each shard runs the full traversal at the same L, so
                    # the merged recall can only match or beat the single
                    # index (DESIGN.md §12's recall argument)
                    assert rec >= base_recall, \
                        (f"{family}/{quant} P={p}: sharded recall {rec:.3f}"
                         f" < single-index {base_recall:.3f}")
    return rows


def main(quick=False, smoke=False, out=None):
    print("n,recall,hops,dists_per_q")
    c_rows = corpus_sweep(quick=quick or smoke)
    for r in c_rows:
        print(f"{r['n']},{r['recall']:.3f},{r['hops']:.1f},{r['dists']:.0f}")
    # sub-linear growth check: dists grow much slower than n
    g_d = c_rows[-1]["dists"] / c_rows[0]["dists"]
    g_n = c_rows[-1]["n"] / c_rows[0]["n"]
    print(f"# dists grew {g_d:.2f}x while n grew {g_n:.1f}x (sub-linear)")

    s_rows = shard_sweep(smoke=smoke or quick)
    print("family,quant,shards,recall,single_recall,dists_per_q,wall_ms")
    for r in s_rows:
        print(f"{r['family']},{r['quant']},{r['shards']},"
              f"{r['recall']:.3f},{r['single_recall']:.3f},"
              f"{r['dists_per_query']:.0f},{r['wall_ms']:.1f}")
    if out:
        report = {"corpus_sweep": c_rows, "shard_sweep": s_rows}
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {out}")
    return {"corpus_sweep": c_rows, "shard_sweep": s_rows}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sharded lane (CI); asserts 1-shard parity "
                         "and multi-shard recall, writes --out JSON")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(quick=args.quick, smoke=args.smoke,
         out=args.out or ("BENCH_scaling.json" if args.smoke else None))
