"""QPS vs recall (paper Fig. 6 / Table 4) on the four dataset analogues.

Baselines are implemented in this framework (same harness, same traversal,
different GRAPH CONSTRUCTION — exactly the axis the paper varies):
  hnsw-style   : alpha rule with a=1.0 (HNSW's heuristic) + no 2-hop refine
  nsg-style    : alpha=1.2, search-based refinement, no 2-hop iterations
  vamana-style : alpha=1.2, 2 search passes (Vamana's two-pass build)
  kbest        : vamana-style + 2-hop iterative refinement (A1) + MST
                 reorder (A2); searched with tuned early termination (A3)

The IVF-PQ family (DESIGN.md §4) rides the same harness as a fifth variant:
its knob is nprobe (probed clusters) instead of L, and its cost driver is
scanned PQ codes (~m byte-reads each) instead of full-precision distances,
so its `dists_per_query` column counts scanned codes + re-ranked exacts.

Wall-clock on this container is CPU-interpreted JAX, so absolute QPS is
meaningless; the table reports (a) per-query distance computations (the
hardware-independent cost driver: QPS ∝ 1/dists at fixed hardware) and
(b) measured relative QPS on CPU for the ablation's sanity.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import ALL_DATASETS, make_dataset, recall_at_k

VARIANTS = {
    "hnsw-style": dict(select_rule="alpha", alpha=1.0, search_passes=0,
                       refine_iters=0, reorder="none"),
    "nsg-style": dict(select_rule="alpha", alpha=1.2, search_passes=1,
                      refine_iters=0, reorder="none"),
    "vamana-style": dict(select_rule="alpha", alpha=1.2, search_passes=2,
                         refine_iters=0, reorder="none"),
    "kbest": dict(select_rule="alpha", alpha=1.2, search_passes=2,
                  refine_iters=1, reorder="mst"),
}


# pq_m per dataset dim (must divide it); nprobe plays the role of L
IVF_PQ_M = {"glove_like": 20, "deep_like": 16, "t2i_like": 20,
            "bigann_like": 16}


def run_ivf(ds, k: int, nprobes=(4, 8, 16, 32)) -> list:
    """The IVF-PQ rows: build once, sweep nprobe (the recall/cost knob)."""
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=8),
        quant=QuantConfig(kind="pq", pq_m=IVF_PQ_M[ds.name], kmeans_iters=6),
        search=SearchConfig(L=128, k=k, nprobe=8))
    idx = KBest(cfg).add(ds.base)
    rows = []
    for nprobe in nprobes:
        s = dataclasses.replace(cfg.search, nprobe=nprobe)
        idx.search(ds.queries[:8], search_cfg=s, with_stats=True)
        t0 = time.perf_counter()
        d, i, st = idx.search(ds.queries, search_cfg=s, with_stats=True)
        np.asarray(d)
        dt = time.perf_counter() - t0
        rows.append({
            "dataset": ds.name, "variant": "ivf-pq", "L": nprobe,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, k),
            "dists_per_query": float(np.asarray(st.n_dist).mean()),
            "hops_per_query": float(np.asarray(st.n_hops).mean()),
            "qps_cpu": ds.queries.shape[0] / dt,
        })
    return rows


def run(n: int = 4000, n_queries: int = 100, k: int = 10,
        Ls=(32, 64, 128, 192, 256), quick: bool = False):
    if quick:
        n, n_queries, Ls = 2000, 50, (32, 64, 128)
    rows = []
    for ds_name in ALL_DATASETS:
        ds = make_dataset(ds_name, n=n, n_queries=n_queries, k=k)
        rows.extend(run_ivf(ds, k, nprobes=(4, 8, 16) if quick
                            else (4, 8, 16, 32)))
        for variant, bkw in VARIANTS.items():
            cfg = IndexConfig(
                dim=ds.base.shape[1], metric=ds.metric,
                build=BuildConfig(M=32, knn_k=48, builder="brute", **bkw),
                search=SearchConfig(L=64, k=k, early_term=False))
            idx = KBest(cfg).add(ds.base)
            for L in Ls:
                # kbest searches with A3 early termination; patience scales
                # with L (the paper binary-searches tau_max per dataset —
                # L/4 is the tuner's typical landing zone, see core/tune.py)
                s = dataclasses.replace(
                    cfg.search, L=L,
                    early_term=(variant == "kbest"),
                    et_patience=max(16, L // 4))
                # warmup + timed
                idx.search(ds.queries[:8], search_cfg=s)
                t0 = time.perf_counter()
                d, i, st = idx.search(ds.queries, search_cfg=s,
                                      with_stats=True)
                np.asarray(d)
                dt = time.perf_counter() - t0
                rows.append({
                    "dataset": ds_name, "variant": variant, "L": L,
                    "recall": recall_at_k(np.asarray(i), ds.gt_ids, k),
                    "dists_per_query": float(np.asarray(st.n_dist).mean()),
                    "hops_per_query": float(np.asarray(st.n_hops).mean()),
                    "qps_cpu": n_queries / dt,
                })
    return rows


def qps_at_recall(rows, target=0.9):
    """Best hardware-independent throughput proxy (1/dists) meeting the
    recall target, per (dataset, variant) — the Table 4 analogue."""
    out = {}
    for r in rows:
        key = (r["dataset"], r["variant"])
        if r["recall"] >= target:
            score = 1.0 / r["dists_per_query"]
            if key not in out or score > out[key][0]:
                out[key] = (score, r)
    return out


def main(quick=False):
    rows = run(quick=quick)
    print("dataset,variant,L,recall,dists_per_query,qps_cpu")
    for r in rows:
        print(f"{r['dataset']},{r['variant']},{r['L']},{r['recall']:.3f},"
              f"{r['dists_per_query']:.0f},{r['qps_cpu']:.1f}")
    print("\n# Table-4 analogue: throughput proxy (1e3/dists) @ recall>=0.9")
    best = qps_at_recall(rows, 0.9)
    for ds in ALL_DATASETS:
        line = [f"{ds:12s}"]
        for v in list(VARIANTS) + ["ivf-pq"]:
            e = best.get((ds, v))
            line.append(f"{v}={1e3*e[0]:.2f}" if e else f"{v}=n/a")
        print("  ".join(line))
    return rows


if __name__ == "__main__":
    main()
