"""QPS vs recall (paper Fig. 6 / Table 4) on the four dataset analogues.

Baselines are implemented in this framework (same harness, same traversal,
different GRAPH CONSTRUCTION — exactly the axis the paper varies):
  hnsw-style   : alpha rule with a=1.0 (HNSW's heuristic) + no 2-hop refine
  nsg-style    : alpha=1.2, search-based refinement, no 2-hop iterations
  vamana-style : alpha=1.2, 2 search passes (Vamana's two-pass build)
  kbest        : vamana-style + 2-hop iterative refinement (A1) + MST
                 reorder (A2); searched with tuned early termination (A3)

The IVF-PQ family (DESIGN.md §4) rides the same harness as a fifth variant:
its knob is nprobe (probed clusters) instead of L, and its cost driver is
scanned PQ codes (~m byte-reads each) instead of full-precision distances,
so its `dists_per_query` column counts scanned codes + re-ranked exacts.
The 4-bit fast-scan family (DESIGN.md §13) adds ivf-pq4 rows at half the
code bytes/vector, plus an ADC microbenchmark (adc_throughput) comparing
pq4's (m, 16) VMEM-resident-LUT scan against 8-bit PQ's (m, 256) gather —
`--pq4-smoke` runs a tiny config of exactly that and emits BENCH_pq4.json
so CI tracks the perf trajectory. The 1-bit sign codec (DESIGN.md §14)
adds ivf-bin rows (u32-packed XOR+popcount Hamming + exact rescore) —
`--bin-smoke` is its CI lane (recall >= 0.85 at >= 8x byte reduction vs
per-dimension pq8, BENCH_bin_smoke.json artifact) and `--bin-bench` the
50k acceptance lane behind the tracked BENCH_bin.json.

Wall-clock on this container is CPU-interpreted JAX, so absolute QPS is
meaningless; the table reports (a) per-query distance computations (the
hardware-independent cost driver: QPS ∝ 1/dists at fixed hardware) and
(b) measured relative QPS on CPU for the ablation's sanity.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core.index import KBest
from repro.core.types import (BuildConfig, IVFConfig, IndexConfig,
                              QuantConfig, SearchConfig)
from repro.data.vectors import ALL_DATASETS, make_dataset, recall_at_k

# IVF sweep rows derive from THE registry (quantize.IVF_QUANT_KINDS) so a
# new IVF-capable kind appears here automatically; the per-kind kwargs are
# run_ivf overrides (bin: two-stage rescore needs the wider queue).
_IVF_KIND_KW = {kind: (dict(rescore_factor=16, L=192) if kind == "bin"
                       else {}) for kind in qz.IVF_QUANT_KINDS}
IVF_VARIANT_NAMES = tuple(f"ivf-{kind}" for kind in qz.IVF_QUANT_KINDS)

VARIANTS = {
    "hnsw-style": dict(select_rule="alpha", alpha=1.0, search_passes=0,
                       refine_iters=0, reorder="none"),
    "nsg-style": dict(select_rule="alpha", alpha=1.2, search_passes=1,
                      refine_iters=0, reorder="none"),
    "vamana-style": dict(select_rule="alpha", alpha=1.2, search_passes=2,
                         refine_iters=0, reorder="none"),
    "kbest": dict(select_rule="alpha", alpha=1.2, search_passes=2,
                  refine_iters=1, reorder="mst"),
}


# pq_m per dataset dim (must divide it); nprobe plays the role of L
IVF_PQ_M = {"glove_like": 20, "deep_like": 16, "t2i_like": 20,
            "bigann_like": 16}


def code_bytes_per_vector(idx: KBest) -> int:
    """Stored code bytes per database vector (the A4 memory axis).

    Delegates to the dtype-aware accounting in core/quantize.py — bin
    codes are uint32 WORDS, not bytes, so shape[-1] alone undercounts 4x."""
    from repro.core import quantize as qz
    return qz.code_bytes_per_vector(idx)


def run_ivf(ds, k: int, nprobes=(4, 8, 16, 32), quant_kind: str = "pq",
            pq_m: int = 0, rescore_factor: int = 8, L: int = 128) -> list:
    """The IVF rows: build once, sweep nprobe (the recall/cost knob).
    quant_kind "pq" (8-bit), "pq4" (4-bit fast-scan, half the bytes) or
    "bin" (1-bit sign codec, DESIGN.md §14 — rescore_factor*k exact
    rescore). pq_m=0 takes the per-dataset default; rescore_factor only
    matters for bin."""
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric, index_type="ivf",
        ivf=IVFConfig(nlist=0, kmeans_iters=8),
        quant=QuantConfig(kind=quant_kind,
                          pq_m=pq_m or IVF_PQ_M[ds.name],
                          kmeans_iters=6),
        search=SearchConfig(L=L, k=k, nprobe=8,
                            rescore_factor=rescore_factor))
    idx = KBest(cfg).add(ds.base)
    rows = []
    for nprobe in nprobes:
        s = dataclasses.replace(cfg.search, nprobe=nprobe)
        idx.search(ds.queries[:8], search_cfg=s, with_stats=True)
        t0 = time.perf_counter()
        d, i, st = idx.search(ds.queries, search_cfg=s, with_stats=True)
        np.asarray(d)
        dt = time.perf_counter() - t0
        rows.append({
            "dataset": ds.name, "variant": f"ivf-{quant_kind}", "L": nprobe,
            "recall": recall_at_k(np.asarray(i), ds.gt_ids, k),
            "dists_per_query": float(np.asarray(st.n_dist).mean()),
            "hops_per_query": float(np.asarray(st.n_hops).mean()),
            "qps_cpu": ds.queries.shape[0] / dt,
            "code_bytes": code_bytes_per_vector(idx),
        })
    return rows


def adc_throughput(ds, n_codes: int = 4096, batch: int = 64,
                   reps: int = 5) -> dict:
    """ADC microbenchmark: pq4 (m, 16) LUT scan vs 8-bit pq (m, 256).

    Times the ref dist fn (XLA-compiled batched gather — the kernels'
    semantic twin; interpret-mode Pallas wall-clock is meaningless on CPU)
    over identical (Q, B) id batches and reports codes scored per second
    plus code bytes/vector. The hardware-independent claim pq4 makes is the
    memory one (half the code bytes, 16x smaller LUT); the measured CPU
    ratio is the sanity check that shrinking the gather axis helps.
    """
    import jax
    from repro.core import quantize as qz

    base = ds.base[:n_codes]
    q = ds.queries[:8]
    Q = q.shape[0]
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, base.shape[0], size=(Q, batch)),
                      jnp.int32)
    out = {}
    for kind in ("pq", "pq4"):
        m = IVF_PQ_M[ds.name]
        cfg = QuantConfig(kind=kind, pq_m=m, kmeans_iters=4)
        st = qz.pq_train(jnp.asarray(base), cfg)
        if kind == "pq4":
            codes = qz.pq4_encode(st.codebooks, jnp.asarray(base))
            tables = qz.pq4_query_tables(st.codebooks, jnp.asarray(q), ds.metric)
            fn = qz.pq4_make_dist_fn(codes, m)
        else:
            codes = qz.pq_encode(st.codebooks, jnp.asarray(base))
            tables = qz.pq_query_tables(st.codebooks, jnp.asarray(q), ds.metric)
            fn = qz.pq_make_dist_fn(codes, m)
        jfn = jax.jit(fn)
        jfn(tables, ids).block_until_ready()            # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jfn(tables, ids).block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        out[kind] = {
            "codes_per_sec": Q * batch / dt,
            "code_bytes": int(codes.shape[-1]),
            "lut_bytes": int(tables.shape[-1]) * 4,
        }
    out["pq4_speedup"] = out["pq4"]["codes_per_sec"] / out["pq"]["codes_per_sec"]
    return out


def run(n: int = 4000, n_queries: int = 100, k: int = 10,
        Ls=(32, 64, 128, 192, 256), quick: bool = False):
    if quick:
        n, n_queries, Ls = 2000, 50, (32, 64, 128)
    rows = []
    for ds_name in ALL_DATASETS:
        ds = make_dataset(ds_name, n=n, n_queries=n_queries, k=k)
        nprobes = (4, 8, 16) if quick else (4, 8, 16, 32)
        # one ivf-<kind> row-set per IVF-capable registry kind; bin's flat
        # Hamming scan needs the wide-queue + deep-rescore overrides
        for kind in _IVF_KIND_KW:
            rows.extend(run_ivf(ds, k, nprobes=nprobes, quant_kind=kind,
                                **_IVF_KIND_KW[kind]))
        for variant, bkw in VARIANTS.items():
            cfg = IndexConfig(
                dim=ds.base.shape[1], metric=ds.metric,
                build=BuildConfig(M=32, knn_k=48, builder="brute", **bkw),
                search=SearchConfig(L=64, k=k, early_term=False))
            idx = KBest(cfg).add(ds.base)
            for L in Ls:
                # kbest searches with A3 early termination; patience scales
                # with L (the paper binary-searches tau_max per dataset —
                # L/4 is the tuner's typical landing zone, see core/tune.py)
                s = dataclasses.replace(
                    cfg.search, L=L,
                    early_term=(variant == "kbest"),
                    et_patience=max(16, L // 4))
                # warmup + timed
                idx.search(ds.queries[:8], search_cfg=s)
                t0 = time.perf_counter()
                d, i, st = idx.search(ds.queries, search_cfg=s,
                                      with_stats=True)
                np.asarray(d)
                dt = time.perf_counter() - t0
                rows.append({
                    "dataset": ds_name, "variant": variant, "L": L,
                    "recall": recall_at_k(np.asarray(i), ds.gt_ids, k),
                    "dists_per_query": float(np.asarray(st.n_dist).mean()),
                    "hops_per_query": float(np.asarray(st.n_hops).mean()),
                    "qps_cpu": n_queries / dt,
                })
    return rows


def qps_at_recall(rows, target=0.9):
    """Best hardware-independent throughput proxy (1/dists) meeting the
    recall target, per (dataset, variant) — the Table 4 analogue."""
    out = {}
    for r in rows:
        key = (r["dataset"], r["variant"])
        if r["recall"] >= target:
            score = 1.0 / r["dists_per_query"]
            if key not in out or score > out[key][0]:
                out[key] = (score, r)
    return out


def pq4_smoke(out: str = "BENCH_pq4.json", n: int = 2000,
              n_queries: int = 32) -> dict:
    """Tiny pq4 lane for CI: ivf-pq4 vs ivf-pq rows on one dataset + the
    ADC microbenchmark, written to `out` so the perf trajectory (ADC
    throughput, code bytes, recall) is recorded per commit."""
    ds = make_dataset("bigann_like", n=n, n_queries=n_queries, k=10)
    rows = (run_ivf(ds, 10, nprobes=(8, 16), quant_kind="pq")
            + run_ivf(ds, 10, nprobes=(8, 16), quant_kind="pq4"))
    adc = adc_throughput(ds)
    by_kind = {v: [r for r in rows if r["variant"] == v]
               for v in ("ivf-pq", "ivf-pq4")}
    # the memory claim is structural — fail the lane loudly if it drifts
    assert by_kind["ivf-pq4"][0]["code_bytes"] * 2 == \
        by_kind["ivf-pq"][0]["code_bytes"], "pq4 must be half of pq8 bytes"
    report = {
        "dataset": ds.name, "n": n, "rows": rows, "adc": adc,
        "best_recall": {v: max(r["recall"] for r in rs)
                        for v, rs in by_kind.items()},
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out}")
    print(f"  code bytes/vec: pq={adc['pq']['code_bytes']} "
          f"pq4={adc['pq4']['code_bytes']}")
    print(f"  ADC codes/s: pq={adc['pq']['codes_per_sec']:.3g} "
          f"pq4={adc['pq4']['codes_per_sec']:.3g} "
          f"(pq4 {adc['pq4_speedup']:.2f}x)")
    print(f"  best recall: {report['best_recall']}")
    return report


def bin_smoke(out: str = "BENCH_bin_smoke.json", n: int = 2000,
              n_queries: int = 32) -> dict:
    """Tiny bin lane for CI (DESIGN.md §14): IVF-bin + graph-bin rows vs an
    equal-per-dimension-resolution pq8 comparator (pq_m=d, i.e. one 8-bit
    code per dimension — the honest baseline for "8x smaller codes": the
    stock pq8 preset already compresses by grouping dims). Asserts the two
    structural claims CI tracks: best bin recall >= 0.85 and >= 8x byte
    reduction vs that pq8. Artifact-only (upload, don't commit)."""
    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    d = ds.base.shape[1]
    rows = run_ivf(ds, 10, nprobes=(16, 24), quant_kind="bin",
                   rescore_factor=16, L=192)
    # graph-bin row
    cfg = IndexConfig(
        dim=d, metric=ds.metric,
        build=BuildConfig(M=24, knn_k=32, builder="brute", refine_iters=0,
                          reorder="none"),
        quant=QuantConfig(kind="bin"),
        search=SearchConfig(L=192, k=10, rescore_factor=16,
                            early_term=False))
    gidx = KBest(cfg).add(ds.base)
    t0 = time.perf_counter()
    _, gi, gst = gidx.search(ds.queries, with_stats=True)
    dt = time.perf_counter() - t0
    bin_bytes = code_bytes_per_vector(gidx)
    rows.append({
        "dataset": ds.name, "variant": "graph-bin", "L": 192,
        "recall": recall_at_k(np.asarray(gi), ds.gt_ids, 10),
        "dists_per_query": float(np.asarray(gst.n_dist).mean()),
        "hops_per_query": float(np.asarray(gst.n_hops).mean()),
        "qps_cpu": ds.queries.shape[0] / dt,
        "code_bytes": bin_bytes,
    })
    # pq8 comparator at pq_m=d: one 8-bit code per dimension (d bytes)
    pq8_rows = run_ivf(ds, 10, nprobes=(16,), quant_kind="pq", pq_m=d)
    pq8_bytes = pq8_rows[0]["code_bytes"]
    best_bin = max(r["recall"] for r in rows)
    assert bin_bytes * 8 <= pq8_bytes, \
        f"bin must be >=8x smaller than per-dim pq8: {bin_bytes} vs {pq8_bytes}"
    assert best_bin >= 0.85, f"bin smoke recall floor: {best_bin:.3f} < 0.85"
    report = {
        "dataset": ds.name, "n": n, "rows": rows + pq8_rows,
        "bin_code_bytes": bin_bytes, "pq8_per_dim_code_bytes": pq8_bytes,
        "byte_reduction_vs_pq8": pq8_bytes / bin_bytes,
        "best_bin_recall": best_bin,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out}")
    print(f"  code bytes/vec: bin={bin_bytes} pq8(m=d)={pq8_bytes} "
          f"({pq8_bytes / bin_bytes:.1f}x reduction)")
    print(f"  best bin recall@10: {best_bin:.3f}")
    return report


def bin_bench(out: str = "BENCH_bin.json", n: int = 50_000,
              n_queries: int = 50) -> dict:
    """The 50k bin acceptance lane (DESIGN.md §14): graph-bin and IVF-bin
    preset configs on the 50k deep_like analogue, recall floor 0.90 with
    rescore, at >= 8x smaller codes than per-dimension pq8 (d u8 codes).
    Writes the tracked BENCH_bin.json baseline."""
    from repro.configs import kbest as kcfg

    import dataclasses

    ds = make_dataset("deep_like", n=n, n_queries=n_queries, k=10)
    d = ds.base.shape[1]
    # graph-bin at 50k needs a much deeper queue than the <=10k preset
    # (DESIGN.md §14): L=640 / rf=64 measures 0.908 vs 0.818 at the
    # preset's L=320 / rf=32
    gcfg = kcfg.bin_index_config("deep_like")
    gcfg = dataclasses.replace(
        gcfg, search=dataclasses.replace(gcfg.search, L=640,
                                         rescore_factor=64,
                                         early_term=False))
    rows = []
    for name, cfg in (("ivf-bin", kcfg.ivf_bin_index_config("deep_like")),
                      ("graph-bin", gcfg)):
        t0 = time.perf_counter()
        idx = KBest(cfg).add(ds.base)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, ids, st = idx.search(ds.queries, with_stats=True)
        dt = time.perf_counter() - t0
        rows.append({
            "dataset": ds.name, "variant": name, "n": n,
            "L": cfg.search.L, "nprobe": cfg.search.nprobe,
            "rescore_factor": cfg.search.rescore_factor,
            "recall": recall_at_k(np.asarray(ids), ds.gt_ids, 10),
            "dists_per_query": float(np.asarray(st.n_dist).mean()),
            "qps_cpu": n_queries / dt, "build_s": build_s,
            "code_bytes": code_bytes_per_vector(idx),
        })
        print(f"  {name}: recall@10={rows[-1]['recall']:.3f} "
              f"build_s={build_s:.0f}", flush=True)
    bin_bytes = rows[0]["code_bytes"]
    report = {
        "dataset": ds.name, "n": n, "rows": rows,
        "bin_code_bytes": bin_bytes,
        "pq8_per_dim_code_bytes": d,        # one u8 code per dimension
        "byte_reduction_vs_pq8": d / bin_bytes,
        "best_bin_recall": max(r["recall"] for r in rows),
    }
    for r in rows:
        assert r["recall"] >= 0.90, (r["variant"], r["recall"])
    assert bin_bytes * 8 <= d, (bin_bytes, d)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out}")
    return report


def main(quick=False):
    rows = run(quick=quick)
    print("dataset,variant,L,recall,dists_per_query,qps_cpu,code_bytes")
    for r in rows:
        print(f"{r['dataset']},{r['variant']},{r['L']},{r['recall']:.3f},"
              f"{r['dists_per_query']:.0f},{r['qps_cpu']:.1f},"
              f"{r.get('code_bytes', '-')}")
    print("\n# Table-4 analogue: throughput proxy (1e3/dists) @ recall>=0.9")
    best = qps_at_recall(rows, 0.9)
    for ds in ALL_DATASETS:
        line = [f"{ds:12s}"]
        for v in list(VARIANTS) + list(IVF_VARIANT_NAMES):
            e = best.get((ds, v))
            line.append(f"{v}={1e3*e[0]:.2f}" if e else f"{v}=n/a")
        print("  ".join(line))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pq4-smoke", action="store_true",
                    help="tiny pq4-vs-pq8 lane; writes --out JSON")
    ap.add_argument("--bin-smoke", action="store_true",
                    help="tiny bin-vs-pq8 lane (recall>=0.85, >=8x bytes); "
                         "writes --out JSON")
    ap.add_argument("--bin-bench", action="store_true",
                    help="50k bin acceptance lane; writes BENCH_bin.json")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.pq4_smoke:
        pq4_smoke(out=args.out or "BENCH_pq4.json")
    elif args.bin_smoke:
        bin_smoke(out=args.out or "BENCH_bin_smoke.json")
    elif args.bin_bench:
        bin_bench(out=args.out or "BENCH_bin.json")
    else:
        main(quick=args.quick)
