"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  [qps_recall]  paper Fig. 6 / Table 4 — QPS-recall curves, 4 datasets,
                4 graph build variants (baselines implemented in-framework)
                + the IVF-PQ family swept over nprobe
  [ablation]    paper Fig. 7 — Base -> +Index -> +EarlyTerm -> +SIMD ->
                +Prefetch
  [scaling]     paper §5.2 — corpus-size sweep + the ShardedKBest shard
                sweep (shards x family x quant, DESIGN.md §12)
  [serving]     beyond-paper — closed/open-loop QPS through the batch-
                serving engine (shape-bucketed compile cache, DESIGN.md §11)
  [traverse]    beyond-paper — beam-width sweep of the lockstep traversal
                (iterations / dists / recall vs W, DESIGN.md §2)
  [roofline]    beyond-paper — cost-model validation on live 5k runs:
                exact n_dist checks + predicted-vs-measured cost ordering
                + roofline table (DESIGN.md §16; writes BENCH_roofline.json)

Each section prints `name,us_per_call,derived` style CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--sections", type=str, default="all")
    ap.add_argument("--bin-smoke", action="store_true",
                    help="run ONLY the bin CI lane (recall >= 0.85 at "
                         ">= 8x byte reduction vs per-dim pq8; writes "
                         "BENCH_bin_smoke.json — artifact-only)")
    ap.add_argument("--cost-smoke", action="store_true",
                    help="run ONLY the cost-model CI lane (exact n_dist "
                         "equality + Spearman >= 0.8 cost ordering at 5k; "
                         "writes BENCH_cost_smoke.json — artifact-only)")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="run ONLY the overload-serving CI lane (2x-"
                         "saturation ramp: policy p99 under SLO + goodput "
                         "over baseline + recall above ladder floor, plus "
                         "the crash-point save/load matrix; writes "
                         "BENCH_serving_smoke.json — artifact-only)")
    args, _ = ap.parse_known_args()
    if args.bin_smoke:
        from benchmarks import qps_recall
        qps_recall.bin_smoke()
        return
    if args.cost_smoke:
        from benchmarks import roofline
        roofline.main(smoke=True, out="BENCH_cost_smoke.json")
        return
    if args.serve_smoke:
        from benchmarks import serving
        serving.overload_main(smoke=True, out="BENCH_serving_smoke.json")
        return
    want = (args.sections.split(",") if args.sections != "all"
            else ["qps_recall", "ablation", "scaling", "serving",
                  "traverse", "roofline"])

    failures = []
    for name in want:
        print(f"\n{'='*72}\n[{name}]\n{'='*72}")
        t0 = time.time()
        try:
            if name == "qps_recall":
                from benchmarks import qps_recall
                qps_recall.main(quick=args.quick)
            elif name == "ablation":
                from benchmarks import ablation
                ablation.main(quick=args.quick)
            elif name == "scaling":
                from benchmarks import scaling
                scaling.main(quick=args.quick)
            elif name == "serving":
                from benchmarks import serving
                serving.main(smoke=args.quick)
            elif name == "traverse":
                from benchmarks import traverse
                # BENCH_traverse.json is the git-tracked 50k baseline —
                # quick (5k) runs must not clobber it
                traverse.main(quick=args.quick,
                              out=("BENCH_traverse_quick.json" if args.quick
                                   else "BENCH_traverse.json"))
            elif name == "roofline":
                from benchmarks import roofline
                # BENCH_roofline.json is the full-report output; --quick
                # keeps the same 5k size (the bench IS the validation)
                roofline.main(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nBENCH FAILURES:", failures)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
