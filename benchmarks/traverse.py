"""Beam-traversal micro-benchmark (DESIGN.md §2): W-sweep at fixed L.

The beam's claim is structural: expanding W nodes per lockstep iteration
cuts the `while_loop` trip count ~W× at (near-)equal recall, because the
per-iteration fixed cost (pick, queue merge, mask bookkeeping) amortizes
over W·M candidates and the gather pipeline has W× more rows in flight to
hide latency behind (H2). Wall-clock on this container is interpret-mode
CPU JAX, so the hardware-independent columns are the ones that matter:
lockstep iterations (the trip count the beam divides) and distance
computations per query (the work the beam must NOT inflate much).

Emits BENCH_traverse.json — unlike the CI-upload-only pq4/scaling
artifacts, the full 50k report is GIT-TRACKED as the committed perf
baseline, so quick/smoke (5k) runs should write elsewhere (--out;
benchmarks/run.py --quick redirects to BENCH_traverse_quick.json):

    PYTHONPATH=src python -m benchmarks.traverse                 # 50k corpus
    PYTHONPATH=src python -m benchmarks.traverse --smoke \
        --out BENCH_traverse_smoke.json                          # CI lane

The smoke lane hard-asserts the structural claims (W=4 cuts iterations
>= 1.5x at recall within 0.005 of W=1) the way the pq4 lane asserts its
byte claim, so CI fails loudly if a refactor quietly serializes the beam.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.index import KBest
from repro.core.types import BuildConfig, IndexConfig, SearchConfig
from repro.data.vectors import make_dataset, recall_at_k

import dataclasses

ITER_RATIO_FLOOR = 1.5    # W=4 must cut lockstep iterations by at least this
RECALL_SLACK = 0.005      # ... at recall within this of W=1


def run(n: int = 50_000, n_queries: int = 100, k: int = 10,
        Ws=(1, 2, 4, 8), L: int = 64, quick: bool = False,
        dataset: str = "deep_like") -> dict:
    """Build one graph index, sweep beam_width at fixed L.

    deep_like is the sweep corpus: it holds a ~0.99 recall floor at L=64,
    so the W rows compare iteration counts at genuinely equal recall
    (bigann_like's integer-rounded mixture is tie-degenerate at these
    sizes — recall ~0.25 for ANY traversal shape — which would make the
    equal-recall comparison meaningless).

    Reports per W: recall@k, lockstep iterations (batch critical path),
    hops & distances per query, and wall-ms per query (CPU sanity only).
    Early termination stays ON (the per-expansion Eq. 3 semantics are part
    of what the sweep validates); an ET-off row pair is included for the
    pure queue-exhaustion trip count.
    """
    if quick:
        n, n_queries = 5_000, 50
    ds = make_dataset(dataset, n=n, n_queries=n_queries, k=k)
    cfg = IndexConfig(
        dim=ds.base.shape[1], metric=ds.metric,
        build=BuildConfig(M=32, knn_k=48, builder="auto",
                          refine_iters=1, refine_cands=96, reorder="mst"),
        search=SearchConfig(L=L, k=k, early_term=True,
                            et_patience=max(16, L // 4)))
    t0 = time.perf_counter()
    idx = KBest(cfg).add(ds.base)
    build_s = time.perf_counter() - t0

    rows = []
    for et in (True, False):
        for W in Ws:
            s = dataclasses.replace(cfg.search, beam_width=W, early_term=et)
            # warm with the EXACT timed call shape (full batch, with_stats):
            # jit keys on operand shapes, so a partial-batch warmup would
            # leave the timed window measuring a fresh trace+compile
            idx.search(ds.queries, search_cfg=s, with_stats=True)
            t0 = time.perf_counter()
            d, i, st = idx.search(ds.queries, search_cfg=s, with_stats=True)
            np.asarray(d)
            dt = time.perf_counter() - t0
            rows.append({
                "W": W, "L": L, "early_term": et,
                "recall": recall_at_k(np.asarray(i), ds.gt_ids, k),
                "iters": int(np.asarray(st.iters)),
                "hops_per_query": float(np.asarray(st.n_hops).mean()),
                "dists_per_query": float(np.asarray(st.n_dist).mean()),
                "et_rate": float(np.asarray(st.early_terminated).mean()),
                "wall_ms_per_query": dt * 1e3 / n_queries,
            })
    return {"dataset": ds.name, "n": n, "n_queries": n_queries, "k": k,
            "L": L, "build_s": build_s, "rows": rows}


def _by_w(report: dict, et: bool) -> dict:
    return {r["W"]: r for r in report["rows"] if r["early_term"] is et}


def check(report: dict) -> dict:
    """The structural claims, computed from a report (and hard-asserted by
    the smoke lane): iteration ratio W=1/W=4 and the recall delta."""
    by_w = _by_w(report, True)
    r1, r4 = by_w[1], by_w[4]
    return {
        "iter_ratio_w4": r1["iters"] / max(r4["iters"], 1),
        "recall_delta_w4": r1["recall"] - r4["recall"],
        "dist_inflation_w4": (r4["dists_per_query"]
                              / max(r1["dists_per_query"], 1.0)),
    }


def main(quick: bool = False, out: str = "BENCH_traverse.json",
         smoke: bool = False) -> dict:
    report = run(quick=quick or smoke)
    report["summary"] = check(report)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out} ({report['dataset']}, n={report['n']}, L={report['L']})")
    print("W,early_term,recall,iters,hops/q,dists/q,et_rate,ms/q")
    for r in report["rows"]:
        print(f"{r['W']},{int(r['early_term'])},{r['recall']:.3f},"
              f"{r['iters']},{r['hops_per_query']:.0f},"
              f"{r['dists_per_query']:.0f},{r['et_rate']:.2f},"
              f"{r['wall_ms_per_query']:.2f}")
    s = report["summary"]
    print(f"# W=4 vs W=1: iters {s['iter_ratio_w4']:.2f}x fewer, "
          f"recall delta {s['recall_delta_w4']:+.4f}, "
          f"dists {s['dist_inflation_w4']:.2f}x")
    if smoke:
        # structural guard, not a tuning target — fail CI loudly if the
        # beam stops beating single expansion on trip count
        assert s["iter_ratio_w4"] >= ITER_RATIO_FLOOR, s
        # one-sided: the beam may only LOSE up to the slack (often it gains
        # recall — the wider frontier expands a superset of nodes)
        assert s["recall_delta_w4"] <= RECALL_SLACK, s
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + hard-assert the beam claims")
    ap.add_argument("--out", default="BENCH_traverse.json")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out, smoke=args.smoke)
